#!/usr/bin/env python3
"""Tiny client for `graphvite serve` — the CI smoke test's query driver.

Speaks the length-prefixed TCP protocol (u32 LE frame length, then a flat
little-endian payload; see rust/src/serve/protocol.rs):

    request  TOPK: [1][flags=0][k u16][nq u32][nq x node-id u32]
    request  INFO: [2]
    response  ok TOPK: [0][nq u32] then per query [m u32][m x (id u32, f32)]
    response  ok INFO: [0][num_nodes u64][dim u32][generation u64]
    response  error:   [1][len u32][len x utf8]

Usage:
    serve_client.py --addr HOST:PORT info
    serve_client.py --addr HOST:PORT topk K NODE [NODE ...]

Prints the decoded response and exits 0 on a well-formed reply, 1 on an
error response, 2 on a protocol violation.
"""

import argparse
import socket
import struct
import sys

MAX_FRAME = 16 << 20


def send_frame(sock, payload: bytes) -> None:
    sock.sendall(struct.pack("<I", len(payload)) + payload)


def recv_exact(sock, n: int) -> bytes:
    buf = b""
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            raise ConnectionError(f"peer closed mid-frame ({len(buf)}/{n} bytes)")
        buf += chunk
    return buf


def recv_frame(sock) -> bytes:
    (length,) = struct.unpack("<I", recv_exact(sock, 4))
    if length > MAX_FRAME:
        raise ValueError(f"peer declared a {length}-byte frame")
    return recv_exact(sock, length)


def decode_topk(payload: bytes):
    if not payload:
        raise ValueError("empty response payload")
    status = payload[0]
    if status == 1:
        (n,) = struct.unpack_from("<I", payload, 1)
        return ("error", payload[5 : 5 + n].decode("utf-8", "replace"))
    if status != 0:
        raise ValueError(f"unknown response status {status}")
    (nq,) = struct.unpack_from("<I", payload, 1)
    at = 5
    results = []
    for _ in range(nq):
        (m,) = struct.unpack_from("<I", payload, at)
        at += 4
        row = []
        for _ in range(m):
            node, score = struct.unpack_from("<If", payload, at)
            at += 8
            row.append((node, score))
        results.append(row)
    if at != len(payload):
        raise ValueError(f"{len(payload) - at} trailing bytes in response")
    return ("ok", results)


def decode_info(payload: bytes):
    status = payload[0]
    if status == 1:
        (n,) = struct.unpack_from("<I", payload, 1)
        return ("error", payload[5 : 5 + n].decode("utf-8", "replace"))
    num_nodes, dim, generation = struct.unpack_from("<QIQ", payload, 1)
    if 1 + 20 != len(payload):
        raise ValueError("info response has the wrong length")
    return ("ok", {"num_nodes": num_nodes, "dim": dim, "generation": generation})


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--addr", default="127.0.0.1:7654", help="server host:port")
    sub = ap.add_subparsers(dest="cmd", required=True)
    sub.add_parser("info")
    topk = sub.add_parser("topk")
    topk.add_argument("k", type=int)
    topk.add_argument("nodes", type=int, nargs="+")
    args = ap.parse_args()

    host, port = args.addr.rsplit(":", 1)
    with socket.create_connection((host, int(port)), timeout=10) as sock:
        if args.cmd == "info":
            send_frame(sock, bytes([2]))
            status, body = decode_info(recv_frame(sock))
        else:
            payload = struct.pack("<BBHI", 1, 0, args.k, len(args.nodes))
            payload += b"".join(struct.pack("<I", v) for v in args.nodes)
            send_frame(sock, payload)
            status, body = decode_topk(recv_frame(sock))

    if status == "error":
        print(f"server error: {body}")
        return 1
    if args.cmd == "info":
        print(f"info: {body['num_nodes']} nodes, dim {body['dim']}, "
              f"generation {body['generation']}")
        return 0
    for node, row in zip(args.nodes, body):
        ranked = " ".join(f"{v}:{s:.4f}" for v, s in row)
        print(f"topk node {node}: {ranked}")
        scores = [s for _, s in row]
        if scores != sorted(scores, reverse=True):
            print("response rows must be ranked by descending score")
            return 2
        if any(v == node for v, _ in row):
            print("self must be excluded from its own neighbor list")
            return 2
    return 0


if __name__ == "__main__":
    try:
        sys.exit(main())
    except (ConnectionError, ValueError, struct.error) as e:
        print(f"protocol violation: {e}")
        sys.exit(2)
