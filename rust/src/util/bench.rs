//! Criterion-style micro/macro benchmark harness (criterion itself is not
//! in the offline crate set). Used by every `rust/benches/*.rs` target
//! (all declared `harness = false`).
//!
//! Features: warmup, configurable sample count, mean/stddev/min reporting,
//! throughput annotations, and a markdown table emitter so each bench can
//! print the paper table it regenerates.

use std::hint::black_box as bb;
use std::time::Instant;

use crate::util::{human_secs, mean, stddev};

/// Re-export of `std::hint::black_box` for bench bodies.
pub fn black_box<T>(x: T) -> T {
    bb(x)
}

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub mean_secs: f64,
    pub stddev_secs: f64,
    pub min_secs: f64,
    pub samples: usize,
    /// Optional items-per-iteration for throughput reporting.
    pub items_per_iter: Option<f64>,
}

impl BenchResult {
    pub fn throughput(&self) -> Option<f64> {
        self.items_per_iter.map(|n| n / self.mean_secs)
    }
}

/// Benchmark runner with warmup + repeated timing.
pub struct Bencher {
    pub warmup_iters: usize,
    pub sample_iters: usize,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    pub fn new() -> Self {
        Bencher { warmup_iters: 3, sample_iters: 10, results: Vec::new() }
    }

    pub fn with_iters(warmup: usize, samples: usize) -> Self {
        Bencher { warmup_iters: warmup, sample_iters: samples, results: Vec::new() }
    }

    /// Run `f` (warmup + samples), record and print one line.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        self.bench_with_items(name, None, &mut f)
    }

    /// Like [`Self::bench`] but annotates items/iteration for throughput.
    pub fn bench_items<T>(
        &mut self,
        name: &str,
        items: f64,
        mut f: impl FnMut() -> T,
    ) -> &BenchResult {
        self.bench_with_items(name, Some(items), &mut f)
    }

    fn bench_with_items<T>(
        &mut self,
        name: &str,
        items: Option<f64>,
        f: &mut dyn FnMut() -> T,
    ) -> &BenchResult {
        for _ in 0..self.warmup_iters {
            bb(f());
        }
        let mut times = Vec::with_capacity(self.sample_iters);
        for _ in 0..self.sample_iters {
            let t0 = Instant::now();
            bb(f());
            times.push(t0.elapsed().as_secs_f64());
        }
        let res = BenchResult {
            name: name.to_string(),
            mean_secs: mean(&times),
            stddev_secs: stddev(&times),
            min_secs: times.iter().cloned().fold(f64::INFINITY, f64::min),
            samples: self.sample_iters,
            items_per_iter: items,
        };
        let thr = res
            .throughput()
            .map(|t| format!("  ({:.3} Melem/s)", t / 1e6))
            .unwrap_or_default();
        println!(
            "bench {:<48} {:>12} ± {:>10}  min {:>12}{}",
            res.name,
            human_secs(res.mean_secs),
            human_secs(res.stddev_secs),
            human_secs(res.min_secs),
            thr
        );
        self.results.push(res);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

/// Markdown table emitter for experiment harnesses: each paper table is
/// regenerated as one of these and printed to stdout.
#[derive(Debug, Default, Clone)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn to_markdown(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<w$} |", c, w = widths[i]));
            }
            s
        };
        let mut out = format!("\n### {}\n\n", self.title);
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{}-|", "-".repeat(w + 1)));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.to_markdown());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bencher_runs_and_records() {
        let mut b = Bencher::with_iters(1, 3);
        let r = b.bench("noop", || 1 + 1).clone();
        assert_eq!(r.samples, 3);
        assert!(r.mean_secs >= 0.0);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn throughput_annotation() {
        let mut b = Bencher::with_iters(0, 2);
        let r = b.bench_items("items", 1000.0, || bb(0)).clone();
        assert!(r.throughput().unwrap() > 0.0);
    }

    #[test]
    fn table_markdown_shape() {
        let mut t = Table::new("Demo", &["a", "b"]);
        t.row(&["1".into(), "xx".into()]);
        let md = t.to_markdown();
        assert!(md.contains("### Demo"));
        assert!(md.lines().filter(|l| l.starts_with('|')).count() == 3);
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn table_arity_checked() {
        let mut t = Table::new("Demo", &["a", "b"]);
        t.row(&["1".into()]);
    }
}
