//! xoshiro256** PRNG + splitmix64 seeding.
//!
//! The offline crate set has no `rand`, so this is the project's RNG
//! substrate: fast (sub-ns per u64), splittable per worker thread (jump
//! via reseeding through splitmix64), with the distribution helpers the
//! samplers need (uniform ranges, f32/f64 unit, shuffling).

/// splitmix64 step — used to expand a single u64 seed into a full state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** — our workhorse PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed deterministically from a single u64 (via splitmix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        // xoshiro must not be seeded with all zeros.
        let all_zero = s.iter().all(|&x| x == 0);
        Rng {
            s: if all_zero { [1, 2, 3, 4] } else { s },
        }
    }

    /// Derive an independent stream for worker `i` (used to give each
    /// sampler / trainer thread its own deterministic RNG).
    pub fn split(&self, i: u64) -> Self {
        let mut sm = self.s[0] ^ self.s[3] ^ (i.wrapping_mul(0xA0761D6478BD642F));
        Rng::new(splitmix64(&mut sm))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform integer in [0, n). n must be > 0.
    /// Lemire's nearly-divisionless bounded sampling.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [0, n).
    #[inline]
    pub fn below_usize(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<u32> {
        let mut p: Vec<u32> = (0..n as u32).collect();
        self.shuffle(&mut p);
        p
    }

    /// Standard normal via Box–Muller (used for embedding init variants).
    pub fn normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..64 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn splits_are_independent_streams() {
        let base = Rng::new(1);
        let mut a = base.split(0);
        let mut b = base.split(1);
        let xs: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..16).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = rng.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval_and_mean() {
        let mut rng = Rng::new(11);
        let mut sum = 0.0;
        const N: usize = 100_000;
        for _ in 0..N {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / N as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(13);
        const N: usize = 50_000;
        let xs: Vec<f64> = (0..N).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / N as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / N as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn zero_seed_is_valid() {
        let mut rng = Rng::new(0);
        assert_ne!(rng.next_u64(), rng.next_u64());
    }
}
