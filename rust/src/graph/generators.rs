//! Synthetic graph generators.
//!
//! The paper's datasets (YouTube, Friendster, Hyperlink-PLD) are
//! multi-hundred-MB downloads we do not have; per the substitution rule
//! (DESIGN.md) every experiment runs on synthetic analogues generated here:
//!
//! * [`barabasi_albert`] — scale-free degree distribution (the structural
//!   property Table 1/3/5 timing claims depend on),
//! * [`planted_partition`] — community-labelled graphs for the
//!   node-classification evaluations (Tables 4/6/7, Figs 4/5),
//! * [`erdos_renyi`] — unstructured control,
//! * [`karate_club`] — Zachary's karate club, a tiny *real* network kept
//!   in-source to anchor correctness end-to-end.

use super::{Graph, GraphBuilder};
use crate::util::rng::Rng;

/// Barabási–Albert preferential attachment: `n` nodes, `m` edges added per
/// new node. Produces the scale-free (power-law) degree distribution that
/// YouTube/Friendster exhibit. O(E) time and memory via the repeated-nodes
/// trick (attachment target sampled uniformly from the endpoint multiset).
pub fn barabasi_albert(n: usize, m: usize, seed: u64) -> Graph {
    assert!(n > m && m >= 1, "need n > m >= 1");
    let mut rng = Rng::new(seed);
    let mut builder = GraphBuilder::new().with_num_nodes(n);
    // endpoint multiset: each edge contributes both endpoints, so sampling
    // uniformly from it is degree-proportional sampling.
    let mut endpoints: Vec<u32> = Vec::with_capacity(2 * n * m);

    // seed clique over the first m+1 nodes
    for u in 0..=(m as u32) {
        for v in (u + 1)..=(m as u32) {
            builder.push_edge(u, v, 1.0);
            endpoints.push(u);
            endpoints.push(v);
        }
    }
    let mut picked: Vec<u32> = Vec::with_capacity(m);
    for u in (m as u32 + 1)..(n as u32) {
        picked.clear();
        // sample m distinct existing nodes, degree-proportionally
        while picked.len() < m {
            let t = endpoints[rng.below_usize(endpoints.len())];
            if !picked.contains(&t) {
                picked.push(t);
            }
        }
        for &t in &picked {
            builder.push_edge(u, t, 1.0);
            endpoints.push(u);
            endpoints.push(t);
        }
    }
    builder.build()
}

/// Planted-partition / SBM-like generator with labels, O(E).
///
/// `n` nodes are split into `k` equal communities (label = community id).
/// `avg_degree` stubs per node; each stub connects within the community
/// with probability `1 - mixing`, otherwise to a uniform random node.
/// `mixing` in [0,1] is the LFR-style mixing parameter: low values give
/// strong community structure (easy classification), high values approach
/// an ER graph.
pub fn planted_partition(
    n: usize,
    k: usize,
    avg_degree: f64,
    mixing: f64,
    seed: u64,
) -> Graph {
    assert!(k >= 1 && n >= 2 * k, "need n >= 2k");
    assert!((0.0..=1.0).contains(&mixing));
    let mut rng = Rng::new(seed);
    let labels: Vec<u16> = (0..n).map(|i| (i % k) as u16).collect();
    // members_of[c] = node ids with label c (round-robin assignment)
    let comm_size = |c: usize| n / k + usize::from(c < n % k);
    let member = |c: usize, j: usize| (j * k + c) as u32; // inverse of i % k

    let num_edges = ((n as f64) * avg_degree / 2.0) as usize;
    let mut builder = GraphBuilder::new().with_num_nodes(n).with_labels(labels);
    for _ in 0..num_edges {
        let u = rng.below_usize(n) as u32;
        let v = if rng.bool(1.0 - mixing) {
            // intra-community partner
            let c = (u as usize) % k;
            let sz = comm_size(c);
            let mut v = member(c, rng.below_usize(sz));
            while v == u {
                v = member(c, rng.below_usize(sz));
            }
            v
        } else {
            let mut v = rng.below_usize(n) as u32;
            while v == u {
                v = rng.below_usize(n) as u32;
            }
            v
        };
        builder.push_edge(u, v, 1.0);
    }
    builder.build()
}

/// Erdős–Rényi G(n, M): exactly `num_edges` uniform random edges.
pub fn erdos_renyi(n: usize, num_edges: usize, seed: u64) -> Graph {
    assert!(n >= 2);
    let mut rng = Rng::new(seed);
    let mut builder = GraphBuilder::new().with_num_nodes(n);
    for _ in 0..num_edges {
        let u = rng.below_usize(n) as u32;
        let mut v = rng.below_usize(n) as u32;
        while v == u {
            v = rng.below_usize(n) as u32;
        }
        builder.push_edge(u, v, 1.0);
    }
    builder.build()
}

/// Zachary's karate club (34 nodes, 78 edges) with the canonical 2-faction
/// split as labels. A real network small enough to embed in-source.
pub fn karate_club() -> Graph {
    const EDGES: [(u32, u32); 78] = [
        (0, 1), (0, 2), (0, 3), (0, 4), (0, 5), (0, 6), (0, 7), (0, 8), (0, 10),
        (0, 11), (0, 12), (0, 13), (0, 17), (0, 19), (0, 21), (0, 31), (1, 2),
        (1, 3), (1, 7), (1, 13), (1, 17), (1, 19), (1, 21), (1, 30), (2, 3),
        (2, 7), (2, 8), (2, 9), (2, 13), (2, 27), (2, 28), (2, 32), (3, 7),
        (3, 12), (3, 13), (4, 6), (4, 10), (5, 6), (5, 10), (5, 16), (6, 16),
        (8, 30), (8, 32), (8, 33), (9, 33), (13, 33), (14, 32), (14, 33),
        (15, 32), (15, 33), (18, 32), (18, 33), (19, 33), (20, 32), (20, 33),
        (22, 32), (22, 33), (23, 25), (23, 27), (23, 29), (23, 32), (23, 33),
        (24, 25), (24, 27), (24, 31), (25, 31), (26, 29), (26, 33), (27, 33),
        (28, 31), (28, 33), (29, 32), (29, 33), (30, 32), (30, 33), (31, 32),
        (31, 33), (32, 33),
    ];
    // Canonical faction split (Mr. Hi = 0, Officer = 1).
    const FACTION1: [u32; 17] = [0, 1, 2, 3, 4, 5, 6, 7, 10, 11, 12, 13, 16, 17, 19, 21, 8];
    let mut labels = vec![1u16; 34];
    for &v in &FACTION1 {
        labels[v as usize] = 0;
    }
    let mut builder = GraphBuilder::new().with_num_nodes(34).with_labels(labels);
    for &(u, v) in &EDGES {
        builder.push_edge(u, v, 1.0);
    }
    builder.build()
}

/// Preset: a scaled-down "YouTube-like" graph — BA scale-free with the
/// paper's |E|/|V| ≈ 4.3 ratio plus planted communities for labels.
/// Used by the Table 3/4 experiments at a size this machine trains in
/// seconds-to-minutes rather than the paper's 1.1M nodes.
pub fn youtube_like(n: usize, num_labels: usize, seed: u64) -> Graph {
    // BA with m=2 gives a power-law tail (the "scale-free" half of the
    // YouTube shape); overlay labels from a planted partition of the node
    // id space so labels correlate with a set of intra-community edges
    // (the "homophily" half). The community overlay must carry a degree
    // comparable to the BA part or embeddings learn only hub-ness and
    // classification stays at chance.
    let ba = barabasi_albert(n, 2, seed);
    let pp = planted_partition(n, num_labels, 6.0, 0.05, seed ^ 0xC0FFEE);
    let mut builder = GraphBuilder::new()
        .with_num_nodes(n)
        .with_labels(pp.labels().unwrap().to_vec());
    for (u, v, w) in ba.edges() {
        builder.push_edge(u, v, w);
    }
    for (u, v, w) in pp.edges() {
        builder.push_edge(u, v, w);
    }
    builder.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ba_shape() {
        let g = barabasi_albert(1000, 3, 1);
        assert_eq!(g.num_nodes(), 1000);
        // m(m+1)/2 clique edges + (n - m - 1) * m attachment edges, minus dedup losses
        let expect = 3 * 4 / 2 + (1000 - 4) * 3;
        assert!(g.num_edges() <= expect && g.num_edges() > expect * 9 / 10);
        // scale-free: max degree far above average
        let max_deg = (0..1000u32).map(|v| g.degree(v)).max().unwrap();
        let avg = 2.0 * g.num_edges() as f64 / 1000.0;
        assert!(max_deg as f64 > 5.0 * avg, "max {max_deg} avg {avg}");
    }

    #[test]
    fn ba_connected_enough() {
        // every node has degree >= m (its own attachments)
        let g = barabasi_albert(500, 2, 3);
        for v in 0..500u32 {
            assert!(g.degree(v) >= 1);
        }
    }

    #[test]
    fn planted_partition_labels_and_mixing() {
        let g = planted_partition(1000, 5, 10.0, 0.1, 7);
        assert_eq!(g.num_nodes(), 1000);
        let labels = g.labels().unwrap();
        assert_eq!(labels.len(), 1000);
        assert!(labels.iter().all(|&l| l < 5));
        // most edges intra-community
        let intra = g
            .edges()
            .filter(|&(u, v, _)| labels[u as usize] == labels[v as usize])
            .count();
        let total = g.num_edges();
        assert!(
            intra as f64 > 0.8 * total as f64,
            "intra {intra} / total {total}"
        );
    }

    #[test]
    fn er_edge_count() {
        let g = erdos_renyi(100, 300, 9);
        assert!(g.num_edges() <= 300); // dedup may merge a few
        assert!(g.num_edges() > 280);
    }

    #[test]
    fn karate_canonical() {
        let g = karate_club();
        assert_eq!(g.num_nodes(), 34);
        assert_eq!(g.num_edges(), 78);
        let labels = g.labels().unwrap();
        assert_eq!(labels[0], 0);
        assert_eq!(labels[33], 1);
    }

    #[test]
    fn youtube_like_has_labels_and_scale() {
        let g = youtube_like(2000, 10, 11);
        assert_eq!(g.num_nodes(), 2000);
        assert!(g.labels().is_some());
        let ratio = g.num_edges() as f64 / g.num_nodes() as f64;
        assert!(ratio > 3.0 && ratio < 7.0, "ratio {ratio}");
    }

    #[test]
    fn generators_deterministic() {
        let a = barabasi_albert(200, 2, 42);
        let b = barabasi_albert(200, 2, 42);
        assert_eq!(a.num_edges(), b.num_edges());
        for v in 0..200u32 {
            assert_eq!(a.neighbors(v), b.neighbors(v));
        }
    }
}
