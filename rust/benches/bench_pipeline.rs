//! Transfer-engine comparison: serial dispatch vs pipelined waves vs
//! pipelined + partition residency vs heterogeneous capacities (the PR-3
//! and PR-4 perf work; no paper table — this tracks the repo's own
//! host↔device data path and capacity-aware scheduler).
//!
//! Run with `cargo bench --bench bench_pipeline`; set
//! `GRAPHVITE_BENCH_SCALE=tiny|small|full` for workload size and
//! `GRAPHVITE_BENCH_FAST=1` for the CI smoke run (single sample).
//!
//! Unlike the table/figure targets this bench **self-records**: besides
//! printing the usual `bench` lines + markdown table it writes
//! `BENCH_pipeline_<scale>.json` next to this file (the benches/README
//! convention), so every run extends the perf trajectory without the
//! shell capture one-liner.

use graphvite::config::{BackendKind, TrainConfig};
use graphvite::coordinator::Trainer;
use graphvite::experiments::{Scale, Workload};
use graphvite::graph::Graph;
use graphvite::metrics::TrainStats;
use graphvite::pool::ShuffleKind;
use graphvite::util::bench::{record_json, Bencher, Table};
use graphvite::util::human_bytes;

fn workload(scale: Scale) -> (Graph, TrainConfig) {
    let nodes = match scale {
        Scale::Tiny => 2_000,
        Scale::Small => 20_000,
        Scale::Full => 100_000,
    };
    let graph = Workload::scale_free(nodes, 5, 0x717);
    let cfg = TrainConfig {
        dim: 64,
        epochs: if scale == Scale::Tiny { 2 } else { 4 },
        num_workers: 2,
        num_partitions: 4, // multi-wave groups: the pipelined case
        num_samplers: 2,
        episode_size: (nodes / 2).max(4_000),
        batch_size: 256,
        fix_context: false, // required for partitions > workers
        backend: BackendKind::best_available(),
        shuffle: ShuffleKind::Pseudo,
        seed: 11,
        ..TrainConfig::default()
    };
    (graph, cfg)
}

fn main() {
    let scale = Scale::from_env();
    let fast = std::env::var("GRAPHVITE_BENCH_FAST").is_ok();
    let mut b = if fast { Bencher::with_iters(0, 1) } else { Bencher::with_iters(1, 3) };

    let (graph, base) = workload(scale);
    let samples = base.total_samples(graph.num_edges()) as f64;
    println!(
        "bench_pipeline scale={} ({} nodes, {} edges, backend {})",
        scale.name(),
        graph.num_nodes(),
        graph.num_edges(),
        base.backend.name()
    );

    // last variant: the same 4-partition grid streamed through 2 unequal
    // "devices" (capacities [1, 3] — one wave of 4 blocks per group,
    // bounded residency caches, capacity-scaled chunks)
    let variants: [(&str, bool, bool, &[usize]); 4] = [
        ("serial", false, false, &[]),
        ("pipelined", true, false, &[]),
        ("pipelined+residency", true, true, &[]),
        ("hetero-caps[1,3]", true, true, &[1, 3]),
    ];
    let mut table = Table::new(
        "Transfer engine: serial vs pipelined vs residency vs hetero capacities",
        &[
            "config",
            "train s",
            "Msamples/s",
            "to-device",
            "from-device",
            "hits",
            "saved",
            "gather+scatter ms",
        ],
    );
    let mut recorded: Vec<String> = Vec::new();

    for (name, pipeline, residency, capacities) in variants {
        let mut last: Option<TrainStats> = None;
        b.bench_items(&format!("train.{name}"), samples, || {
            let cfg = TrainConfig {
                pipeline_transfers: pipeline,
                residency,
                worker_capacities: capacities.to_vec(),
                ..base.clone()
            };
            let mut t = Trainer::new(graph.clone(), cfg).unwrap();
            let r = t.train().unwrap();
            let trained = r.stats.counters.samples_trained;
            last = Some(r.stats);
            trained
        });
        let s = last.expect("bench ran at least once");
        let c = &s.counters;
        table.row(&[
            name.to_string(),
            format!("{:.3}", s.train_secs),
            format!("{:.3}", s.throughput() / 1e6),
            human_bytes(c.bytes_to_device),
            human_bytes(c.bytes_from_device),
            c.residency_hits.to_string(),
            human_bytes(c.bytes_saved),
            format!("{:.1}", s.transfer_secs() * 1e3),
        ]);
        recorded.push(format!(
            "counters {name}: train_secs {:.6} samples_trained {} bytes_to_device {} \
             bytes_from_device {} residency_hits {} bytes_saved {} gather_nanos {} \
             scatter_nanos {}",
            s.train_secs,
            c.samples_trained,
            c.bytes_to_device,
            c.bytes_from_device,
            c.residency_hits,
            c.bytes_saved,
            c.gather_nanos,
            c.scatter_nanos
        ));
    }

    table.print();
    for line in &recorded {
        println!("{line}");
    }

    // self-record per the benches/README BENCH_*.json convention
    let mut lines = b.result_lines();
    lines.extend(table.to_markdown().lines().map(String::from));
    lines.extend(recorded.iter().cloned());
    let path = format!(
        "{}/benches/BENCH_pipeline_{}.json",
        env!("CARGO_MANIFEST_DIR"),
        scale.name()
    );
    record_json(&path, &format!("bench_pipeline scale={}", scale.name()), &lines);
}
