//! Full-pipeline integration tests: the complete three-layer system
//! (rust coordinator → PJRT-loaded AOT HLO from JAX+Pallas) on small real
//! workloads. These are the tests that prove the layers compose.
//!
//! Compiled only with `--features pjrt` (and they additionally need real
//! PJRT bindings plus the AOT artifacts at run time); the default feature
//! set covers the same coordinator paths through the native backend in
//! `integration.rs`.
#![cfg(feature = "pjrt")]

use graphvite::config::{BackendKind, TrainConfig};
use graphvite::coordinator::Trainer;
use graphvite::graph::generators;
use graphvite::pool::ShuffleKind;

fn hlo_cfg() -> TrainConfig {
    TrainConfig {
        dim: 16,
        epochs: 2,
        num_workers: 2,
        num_samplers: 2,
        episode_size: 1_000,
        batch_size: 256, // hlo chunk = s*b from the artifact, this is unused
        backend: BackendKind::Pjrt,
        shuffle: ShuffleKind::Pseudo,
        ..TrainConfig::default()
    }
}

#[test]
fn hlo_backend_trains_small_graph() {
    let g = generators::barabasi_albert(200, 3, 11);
    let mut t = Trainer::new(g, hlo_cfg()).unwrap();
    let r = t.train().unwrap();
    assert_eq!(r.embeddings.num_nodes(), 200);
    assert!(r.stats.counters.samples_trained > 0);
    assert!(r.stats.final_loss.is_finite());
    assert!(r.stats.counters.device_steps > 0, "no PJRT executes happened");
}

#[test]
fn hlo_loss_decreases_on_structured_graph() {
    let g = generators::planted_partition(240, 4, 16.0, 0.05, 13);
    let cfg = TrainConfig { epochs: 30, ..hlo_cfg() };
    let mut t = Trainer::new(g, cfg).unwrap();
    let r = t.train().unwrap();
    let curve = &r.stats.loss_curve;
    assert!(curve.len() >= 4, "curve too short: {curve:?}");
    let head: f32 = curve[..2].iter().sum::<f32>() / 2.0;
    let tail: f32 = curve[curve.len() - 2..].iter().sum::<f32>() / 2.0;
    assert!(tail < head, "loss did not decrease: head {head} tail {tail}");
}

#[test]
fn hlo_and_native_agree_on_loss_trajectory() {
    // Same graph, same seed: the two backends use the same batch semantics
    // (gather → grad at pre-update values → scatter-add), so their loss
    // curves should land in the same region even though chunk sizes differ.
    let g = generators::planted_partition(240, 4, 16.0, 0.05, 17);
    let epochs = 12;
    let run = |backend| {
        let cfg = TrainConfig { epochs, backend, ..hlo_cfg() };
        let mut t = Trainer::new(g.clone(), cfg).unwrap();
        t.train().unwrap().stats.final_loss
    };
    let hlo = run(BackendKind::Pjrt);
    let native = run(BackendKind::Native);
    assert!(hlo.is_finite() && native.is_finite());
    assert!(
        (hlo - native).abs() < 0.35,
        "backends diverged: hlo {hlo} native {native}"
    );
}

#[test]
fn fix_context_hlo_roundtrip_preserves_state() {
    // The bus-usage optimization keeps context partitions device-resident;
    // the final drain must still deliver a fully updated store.
    let g = generators::barabasi_albert(150, 3, 19);
    let cfg = TrainConfig { fix_context: true, ..hlo_cfg() };
    let mut t = Trainer::new(g, cfg).unwrap();
    let r = t.train().unwrap();
    // context matrix must have moved away from its all-zeros init
    let ctx = r.embeddings.context_matrix();
    let nonzero = ctx.iter().filter(|x| **x != 0.0).count();
    assert!(
        nonzero > ctx.len() / 10,
        "context matrix looks untrained ({nonzero}/{} nonzero)",
        ctx.len()
    );
}
