//! Transfer-engine regression suite: the pipelined wave dispatch and the
//! generalized partition residency are *pure data-movement* optimizations
//! — they must not change a single trained float.
//!
//! Why bitwise equivalence holds (and what these tests pin down):
//! * waves inside an episode group are mutually row/column-disjoint, so
//!   scatters of in-flight waves commute with the next wave's gathers;
//! * per-worker job order is identical whether or not dispatch waits for
//!   results, so each worker's RNG stream sees the same draws;
//! * the LR schedule is driven by *dispatched* samples (a job trains
//!   exactly its block length), which serial and pipelined dispatch agree
//!   on at every wave boundary.
//!
//! Residency additionally must strictly reduce `bytes_to_device` against
//! the PR-2 transfer pattern (`residency = false`), with the exact
//! accounting identity `bytes_to_device + bytes_saved == baseline bytes`.

use graphvite::config::{BackendKind, TrainConfig};
use graphvite::coordinator::{TrainResult, Trainer};
use graphvite::graph::{generators, Graph};
use graphvite::pool::ShuffleKind;

fn base_cfg() -> TrainConfig {
    TrainConfig {
        dim: 8,
        epochs: 4,
        num_workers: 2,
        num_partitions: 4, // 2 waves per group: the pipelined case
        num_samplers: 2,
        episode_size: 2_000,
        batch_size: 64,
        fix_context: false, // required for num_partitions > num_workers
        // CI's backend matrix re-runs this suite per backend via
        // GRAPHVITE_TEST_BACKEND (default: native)
        backend: BackendKind::test_backend(),
        shuffle: ShuffleKind::Pseudo,
        seed: 77,
        ..TrainConfig::default()
    }
}

fn graph() -> Graph {
    generators::planted_partition(400, 4, 12.0, 0.05, 31)
}

fn run(g: &Graph, cfg: TrainConfig) -> TrainResult {
    let mut t = Trainer::new(g.clone(), cfg).unwrap();
    t.train().unwrap()
}

#[test]
fn pipelined_dispatch_is_bitwise_equivalent_to_serial() {
    let g = graph();
    for residency in [false, true] {
        let serial = run(
            &g,
            TrainConfig { pipeline_transfers: false, residency, ..base_cfg() },
        );
        let pipelined = run(
            &g,
            TrainConfig { pipeline_transfers: true, residency, ..base_cfg() },
        );
        assert_eq!(
            serial.embeddings.vertex_matrix(),
            pipelined.embeddings.vertex_matrix(),
            "vertex matrices diverged (residency={residency})"
        );
        assert_eq!(
            serial.embeddings.context_matrix(),
            pipelined.embeddings.context_matrix(),
            "context matrices diverged (residency={residency})"
        );
        assert_eq!(
            serial.stats.counters.samples_trained,
            pipelined.stats.counters.samples_trained
        );
        assert!(pipelined.stats.final_loss.is_finite());
    }
}

#[test]
fn legacy_fix_context_path_is_bitwise_equivalent() {
    // The §3.4 context cache (residency = false, fix_context = true) now
    // runs through the same shipment/residency machinery — pin its
    // equivalence across dispatch modes too.
    let g = graph();
    let legacy = TrainConfig {
        num_partitions: 0, // fix_context requires partitions == workers
        fix_context: true,
        residency: false,
        ..base_cfg()
    };
    let serial = run(&g, TrainConfig { pipeline_transfers: false, ..legacy.clone() });
    let pipelined = run(&g, TrainConfig { pipeline_transfers: true, ..legacy });
    assert_eq!(
        serial.embeddings.vertex_matrix(),
        pipelined.embeddings.vertex_matrix()
    );
    assert_eq!(
        serial.embeddings.context_matrix(),
        pipelined.embeddings.context_matrix()
    );
}

#[test]
fn overlapped_pool_refill_is_bitwise_equivalent() {
    // Collaboration mode now takes + redistributes the NEXT pool on a
    // helper thread while the previous pool's final group drains (the
    // overlapped refill). Both modes fill pools from the same pinned
    // sampler streams and consume them identically, so collaboration
    // on (overlapped refill) vs off (fill-then-consume on one thread)
    // must be bitwise-equivalent — which also pins that the overlap is
    // pure scheduling. epochs=4 over this pool size gives several pools,
    // so the prefetched-grid handoff path actually runs.
    let g = graph();
    for pipeline in [false, true] {
        let overlapped = run(
            &g,
            TrainConfig { collaboration: true, pipeline_transfers: pipeline, ..base_cfg() },
        );
        let sequential = run(
            &g,
            TrainConfig { collaboration: false, pipeline_transfers: pipeline, ..base_cfg() },
        );
        assert_eq!(
            overlapped.embeddings.vertex_matrix(),
            sequential.embeddings.vertex_matrix(),
            "vertex matrices diverged (pipeline={pipeline})"
        );
        assert_eq!(
            overlapped.embeddings.context_matrix(),
            sequential.embeddings.context_matrix(),
            "context matrices diverged (pipeline={pipeline})"
        );
        assert_eq!(
            overlapped.stats.counters.samples_trained,
            sequential.stats.counters.samples_trained
        );
    }
}

#[test]
fn residency_strictly_reduces_bytes_to_device() {
    // 4 partitions / 2 workers: the ISSUE's acceptance scenario. The two
    // runs dispatch the same multiset of jobs (group *order* differs, the
    // set does not), so the transfer ledger must balance exactly.
    let g = graph();
    let baseline = run(&g, TrainConfig { residency: false, ..base_cfg() });
    let resident = run(&g, TrainConfig { residency: true, ..base_cfg() });
    let b = &baseline.stats.counters;
    let r = &resident.stats.counters;

    assert_eq!(b.residency_hits, 0, "PR-2 pattern must never elide uploads");
    assert_eq!(b.samples_trained, r.samples_trained);
    assert!(r.residency_hits > 0, "residency mode produced no hits");
    assert!(r.bytes_saved > 0);
    assert!(
        r.bytes_to_device < b.bytes_to_device,
        "residency did not reduce uploads: {} vs {}",
        r.bytes_to_device,
        b.bytes_to_device
    );
    // every byte not shipped is a byte saved — the ledger balances
    assert_eq!(
        r.bytes_to_device + r.bytes_saved,
        b.bytes_to_device,
        "saved-bytes accounting does not balance"
    );
    // the host-side transfer timers actually run
    assert!(b.gather_nanos > 0 && b.scatter_nanos > 0);
    assert!(resident.stats.final_loss.is_finite());
}

#[test]
fn residency_survives_checkpoint_syncs() {
    // Checkpoints force a sync fence (workers clone resident partitions
    // back); residency hits must keep accruing afterwards and the final
    // store must be fully synchronized (finite, trained values).
    let g = graph();
    let mut cfg = TrainConfig { residency: true, ..base_cfg() };
    cfg.episode_size = 500; // several pools => several checkpoints
    let mut t = Trainer::new(g.clone(), cfg).unwrap();
    let mut calls = 0u32;
    let mut cb = |done: u64, store: &graphvite::embedding::EmbeddingStore| {
        assert!(done > 0);
        // synced at the fence: every row is finite (stale-free read)
        assert!(store.vertex_matrix().iter().all(|x| x.is_finite()));
        assert!(store.context_matrix().iter().all(|x| x.is_finite()));
        calls += 1;
    };
    let r = t.train_with_callback(Some(&mut cb)).unwrap();
    assert!(calls >= 2, "expected several checkpoints, got {calls}");
    assert!(r.stats.counters.residency_hits > 0);
}
