//! Criterion-style micro/macro benchmark harness (criterion itself is not
//! in the offline crate set). Used by every `rust/benches/*.rs` target
//! (all declared `harness = false`).
//!
//! Features: warmup, configurable sample count, mean/stddev/min reporting,
//! throughput annotations, and a markdown table emitter so each bench can
//! print the paper table it regenerates.

use std::hint::black_box as bb;
use std::time::Instant;

use crate::util::{human_secs, mean, stddev};

/// Re-export of `std::hint::black_box` for bench bodies.
pub fn black_box<T>(x: T) -> T {
    bb(x)
}

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub mean_secs: f64,
    pub stddev_secs: f64,
    pub min_secs: f64,
    pub samples: usize,
    /// Optional items-per-iteration for throughput reporting.
    pub items_per_iter: Option<f64>,
}

impl BenchResult {
    pub fn throughput(&self) -> Option<f64> {
        self.items_per_iter.map(|n| n / self.mean_secs)
    }
}

/// Benchmark runner with warmup + repeated timing.
pub struct Bencher {
    pub warmup_iters: usize,
    pub sample_iters: usize,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    pub fn new() -> Self {
        Bencher { warmup_iters: 3, sample_iters: 10, results: Vec::new() }
    }

    pub fn with_iters(warmup: usize, samples: usize) -> Self {
        Bencher { warmup_iters: warmup, sample_iters: samples, results: Vec::new() }
    }

    /// Run `f` (warmup + samples), record and print one line.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        self.bench_with_items(name, None, &mut f)
    }

    /// Like [`Self::bench`] but annotates items/iteration for throughput.
    pub fn bench_items<T>(
        &mut self,
        name: &str,
        items: f64,
        mut f: impl FnMut() -> T,
    ) -> &BenchResult {
        self.bench_with_items(name, Some(items), &mut f)
    }

    fn bench_with_items<T>(
        &mut self,
        name: &str,
        items: Option<f64>,
        f: &mut dyn FnMut() -> T,
    ) -> &BenchResult {
        for _ in 0..self.warmup_iters {
            bb(f());
        }
        let mut times = Vec::with_capacity(self.sample_iters);
        for _ in 0..self.sample_iters {
            let t0 = Instant::now();
            bb(f());
            times.push(t0.elapsed().as_secs_f64());
        }
        let res = BenchResult {
            name: name.to_string(),
            mean_secs: mean(&times),
            stddev_secs: stddev(&times),
            min_secs: times.iter().cloned().fold(f64::INFINITY, f64::min),
            samples: self.sample_iters,
            items_per_iter: items,
        };
        let thr = res
            .throughput()
            .map(|t| format!("  ({:.3} Melem/s)", t / 1e6))
            .unwrap_or_default();
        println!(
            "bench {:<48} {:>12} ± {:>10}  min {:>12}{}",
            res.name,
            human_secs(res.mean_secs),
            human_secs(res.stddev_secs),
            human_secs(res.min_secs),
            thr
        );
        self.results.push(res);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// The standard machine-greppable `bench …` record lines for a
    /// self-recorded `BENCH_*.json` (see `rust/benches/README.md`).
    pub fn result_lines(&self) -> Vec<String> {
        self.results
            .iter()
            .map(|r| {
                let thr = r
                    .throughput()
                    .map(|t| format!(" ({t:.0}/s)"))
                    .unwrap_or_default();
                format!(
                    "bench {} {:.9} ± {:.9} min {:.9}{thr}",
                    r.name, r.mean_secs, r.stddev_secs, r.min_secs
                )
            })
            .collect()
    }
}

/// Serialize bench output lines as the `rust/benches/README.md`
/// `BENCH_*.json` shape — `{"argv": …, "lines": […]}` (the offline crate
/// set has no serde, so this is a minimal hand-rolled emitter).
pub fn to_json(argv: &str, lines: &[String]) -> String {
    fn esc(s: &str) -> String {
        let mut out = String::with_capacity(s.len() + 2);
        for ch in s.chars() {
            match ch {
                '"' => out.push_str("\\\""),
                '\\' => out.push_str("\\\\"),
                '\n' => out.push_str("\\n"),
                c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                c => out.push(c),
            }
        }
        out
    }
    let mut json = String::from("{\n");
    json.push_str(&format!(" \"argv\": \"{}\",\n", esc(argv)));
    json.push_str(" \"lines\": [\n");
    for (i, line) in lines.iter().enumerate() {
        let comma = if i + 1 == lines.len() { "" } else { "," };
        json.push_str(&format!("  \"{}\"{comma}\n", esc(line)));
    }
    json.push_str(" ]\n}\n");
    json
}

/// Write a self-recorded `BENCH_*.json`, reporting rather than failing on
/// I/O errors (CI runners and read-only checkouts must not abort a bench
/// run at the very end).
pub fn record_json(path: &str, argv: &str, lines: &[String]) {
    match std::fs::write(path, to_json(argv, lines)) {
        Ok(()) => println!("recorded {path}"),
        Err(e) => eprintln!("could not record {path}: {e}"),
    }
}

/// Markdown table emitter for experiment harnesses: each paper table is
/// regenerated as one of these and printed to stdout.
#[derive(Debug, Default, Clone)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn to_markdown(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<w$} |", c, w = widths[i]));
            }
            s
        };
        let mut out = format!("\n### {}\n\n", self.title);
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{}-|", "-".repeat(w + 1)));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.to_markdown());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bencher_runs_and_records() {
        let mut b = Bencher::with_iters(1, 3);
        let r = b.bench("noop", || 1 + 1).clone();
        assert_eq!(r.samples, 3);
        assert!(r.mean_secs >= 0.0);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn throughput_annotation() {
        let mut b = Bencher::with_iters(0, 2);
        let r = b.bench_items("items", 1000.0, || bb(0)).clone();
        assert!(r.throughput().unwrap() > 0.0);
    }

    #[test]
    fn table_markdown_shape() {
        let mut t = Table::new("Demo", &["a", "b"]);
        t.row(&["1".into(), "xx".into()]);
        let md = t.to_markdown();
        assert!(md.contains("### Demo"));
        assert!(md.lines().filter(|l| l.starts_with('|')).count() == 3);
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn table_arity_checked() {
        let mut t = Table::new("Demo", &["a", "b"]);
        t.row(&["1".into()]);
    }

    #[test]
    fn result_lines_are_greppable() {
        let mut b = Bencher::with_iters(0, 2);
        b.bench("plain", || 1);
        b.bench_items("throughput", 100.0, || 1);
        let lines = b.result_lines();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].starts_with("bench plain "));
        assert!(lines[0].contains(" min "));
        assert!(!lines[0].contains("/s)"));
        assert!(lines[1].starts_with("bench throughput "));
        assert!(lines[1].ends_with("/s)"));
    }

    #[test]
    fn json_record_escapes_and_shapes() {
        let json = to_json("demo scale=tiny", &["a \"quoted\" line".into(), "b".into()]);
        assert!(json.starts_with("{\n"));
        assert!(json.contains("\"argv\": \"demo scale=tiny\""));
        assert!(json.contains("a \\\"quoted\\\" line"));
        assert!(json.trim_end().ends_with('}'));
        // empty line set still emits a valid shape
        let empty = to_json("x", &[]);
        assert!(empty.contains("\"lines\": [\n ]"));
    }
}
