//! Restricted negative sampling (paper §3.2).
//!
//! GraphVite draws negatives with p ∝ degree^0.75 (word2vec's unigram
//! power), but — crucially — **only from the context partition resident on
//! the current GPU**, so no inter-GPU communication is ever needed for
//! negatives. This module builds one alias table per context partition
//! over the partition's member degrees; samples are *partition-local row
//! indices*, ready to feed the device trainer.

use crate::graph::GraphStore;
use crate::partition::Partitioning;
use crate::sampling::AliasTable;
use crate::util::rng::Rng;

/// word2vec / LINE negative-sampling degree power.
pub const NEG_POWER: f32 = 0.75;

/// Per-partition restricted negative sampler.
pub struct NegativeSampler {
    /// One table per partition, over that partition's local rows.
    tables: Vec<AliasTable>,
}

impl NegativeSampler {
    /// Build from the graph degrees and a partitioning. Table `p` is over
    /// partition `p`'s nodes in *local-row order*, weighted deg^0.75.
    /// Weighted degrees are resident for every [`GraphStore`], so this
    /// never touches an out-of-core store's successor pages.
    pub fn new(graph: &dyn GraphStore, partitioning: &Partitioning) -> Self {
        Self::from_weights(&Self::partition_weights(graph, partitioning))
    }

    /// The per-partition deg^0.75 weights [`Self::new`] builds its tables
    /// from, in local-row order. The socket transport ships these f32s
    /// bit-exactly in the worker handshake so a remote worker (which has
    /// no graph) reconstructs the *identical* alias tables —
    /// [`AliasTable::new`] is deterministic in its input bits.
    pub fn partition_weights(
        graph: &dyn GraphStore,
        partitioning: &Partitioning,
    ) -> Vec<Vec<f32>> {
        (0..partitioning.num_parts())
            .map(|p| {
                partitioning
                    .nodes_of_part(p)
                    .iter()
                    .map(|&v| graph.weighted_degree(v).max(1e-12).powf(NEG_POWER))
                    .collect()
            })
            .collect()
    }

    /// Build directly from per-partition weight vectors (the remote-worker
    /// path; see [`Self::partition_weights`]).
    pub fn from_weights(weights: &[Vec<f32>]) -> Self {
        NegativeSampler { tables: weights.iter().map(|w| AliasTable::new(w)).collect() }
    }

    /// Draw one negative as a local row index within partition `part`.
    #[inline]
    pub fn sample_local(&self, part: usize, rng: &mut Rng) -> u32 {
        self.tables[part].sample(rng)
    }

    /// Fill `out` with `count` local-row negatives for partition `part`.
    pub fn fill_local(&self, part: usize, count: usize, rng: &mut Rng, out: &mut Vec<i32>) {
        out.clear();
        out.reserve(count);
        for _ in 0..count {
            out.push(self.tables[part].sample(rng) as i32);
        }
    }

    pub fn num_parts(&self) -> usize {
        self.tables.len()
    }

    /// Total memory of all tables (Table 1 accounting).
    pub fn bytes(&self) -> usize {
        self.tables.iter().map(|t| t.bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::partition::Partitioner;

    #[test]
    fn locals_are_in_partition_range() {
        let g = generators::barabasi_albert(200, 3, 1);
        let parts = Partitioner::degree_zigzag(&g, 4);
        let neg = NegativeSampler::new(&g, &parts);
        let mut rng = Rng::new(1);
        for p in 0..4 {
            let size = parts.part_size(p);
            for _ in 0..100 {
                assert!((neg.sample_local(p, &mut rng) as usize) < size);
            }
        }
    }

    #[test]
    fn distribution_follows_degree_power() {
        let g = generators::barabasi_albert(100, 2, 2);
        let parts = Partitioner::degree_zigzag(&g, 1); // single partition
        let neg = NegativeSampler::new(&g, &parts);
        let mut rng = Rng::new(2);
        let nodes = parts.nodes_of_part(0);
        let mut counts = vec![0usize; nodes.len()];
        const N: usize = 200_000;
        for _ in 0..N {
            counts[neg.sample_local(0, &mut rng) as usize] += 1;
        }
        let weights: Vec<f64> = nodes
            .iter()
            .map(|&v| (g.weighted_degree(v) as f64).powf(0.75))
            .collect();
        let total: f64 = weights.iter().sum();
        // spot-check the top-degree node's frequency
        let (argmax, wmax) = weights
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, w)| (i, *w))
            .unwrap();
        let f = counts[argmax] as f64 / N as f64;
        assert!((f - wmax / total).abs() < 0.01, "f={f} expect={}", wmax / total);
    }

    #[test]
    fn fill_local_count_and_range() {
        let g = generators::karate_club();
        let parts = Partitioner::degree_zigzag(&g, 2);
        let neg = NegativeSampler::new(&g, &parts);
        let mut rng = Rng::new(3);
        let mut out = Vec::new();
        neg.fill_local(1, 64, &mut rng, &mut out);
        assert_eq!(out.len(), 64);
        assert!(out.iter().all(|&x| (x as usize) < parts.part_size(1)));
    }
}
