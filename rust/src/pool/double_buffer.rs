//! The collaboration strategy's double-buffered pool pair (paper §3.3).
//!
//! Two sample pools live in main memory; CPU sampler threads always fill
//! one while GPU workers train from the other, and the pair swaps when the
//! producer finishes — so neither stage ever waits on the other inside an
//! episode and the hardware-idle-half problem of a single shared pool
//! disappears.
//!
//! Implemented as a rendezvous: the producer publishes a filled pool and
//! blocks until the consumer returns the previous one (1-deep exchange —
//! exactly two buffers ever exist, like the paper's layout).

use std::sync::{Condvar, Mutex};

use super::SamplePool;

#[derive(Debug, Default)]
struct State {
    /// Filled pool waiting for the consumer (capacity 1).
    ready: Option<SamplePool>,
    /// Empty pool returned by the consumer for the producer to refill.
    free: Option<SamplePool>,
    /// Producer signalled end of stream.
    done: bool,
    /// Consumer abandoned the stream (error path): producer must stop.
    closed: bool,
}

/// Shared double-buffer exchange between one producer and one consumer.
#[derive(Debug, Default)]
pub struct PoolPair {
    state: Mutex<State>,
    cond: Condvar,
}

impl PoolPair {
    pub fn new() -> Self {
        // seed the producer with one free buffer; the second buffer is the
        // one the producer allocates for its first fill.
        let s = State { free: Some(SamplePool::new()), ..State::default() };
        PoolPair { state: Mutex::new(s), cond: Condvar::new() }
    }

    /// Producer: publish a filled pool; blocks while the previous one is
    /// still unconsumed (keeps exactly 2 pools alive). Returns an empty
    /// buffer to refill, or `None` once the consumer has [`Self::close`]d
    /// the pair (its error path) — the producer must stop producing.
    pub fn publish(&self, pool: SamplePool) -> Option<SamplePool> {
        let mut st = self.state.lock().unwrap();
        while st.ready.is_some() && !st.closed {
            st = self.cond.wait(st).unwrap();
        }
        if st.closed {
            return None;
        }
        st.ready = Some(pool);
        self.cond.notify_all();
        while st.free.is_none() && !st.closed {
            st = self.cond.wait(st).unwrap();
        }
        if st.closed {
            return None;
        }
        let mut buf = st.free.take().unwrap();
        buf.clear();
        Some(buf)
    }

    /// Consumer: take the next filled pool, blocking until one is ready.
    /// Returns None after [`Self::finish`] once the stream drains.
    pub fn take(&self) -> Option<SamplePool> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(pool) = st.ready.take() {
                self.cond.notify_all();
                return Some(pool);
            }
            if st.done {
                return None;
            }
            st = self.cond.wait(st).unwrap();
        }
    }

    /// Consumer: hand a drained pool back for refilling.
    pub fn recycle(&self, pool: SamplePool) {
        let mut st = self.state.lock().unwrap();
        st.free = Some(pool);
        self.cond.notify_all();
    }

    /// Producer: signal end of stream.
    pub fn finish(&self) {
        let mut st = self.state.lock().unwrap();
        st.done = true;
        self.cond.notify_all();
    }

    /// Consumer: abandon the stream (error path). Wakes and permanently
    /// unblocks a producer parked in [`Self::publish`], which then
    /// returns `None` — without this, an error on the consumer side
    /// would leave the producer blocked forever and the training scope
    /// would hang in its implicit join instead of surfacing the error.
    pub fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        self.cond.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn producer_consumer_overlap() {
        let pair = Arc::new(PoolPair::new());
        let producer = {
            let pair = Arc::clone(&pair);
            std::thread::spawn(move || {
                let mut buf = SamplePool::new();
                for round in 0..5u32 {
                    buf.clear();
                    buf.extend((0..100).map(|i| (round, i)));
                    buf = pair.publish(buf).expect("consumer alive");
                }
                pair.finish();
            })
        };
        let mut rounds = Vec::new();
        while let Some(pool) = pair.take() {
            assert_eq!(pool.len(), 100);
            rounds.push(pool[0].0);
            pair.recycle(pool);
        }
        producer.join().unwrap();
        assert_eq!(rounds, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn close_unblocks_parked_producer() {
        // the consumer-error path: producer is parked in publish (second
        // pool, first never taken); close() must wake it with None so the
        // thread exits instead of hanging the scope join
        let pair = Arc::new(PoolPair::new());
        let p2 = Arc::clone(&pair);
        let producer = std::thread::spawn(move || {
            let mut buf = SamplePool::new();
            let mut published = 0u32;
            loop {
                buf.push((0, 0));
                match p2.publish(buf) {
                    Some(b) => {
                        buf = b;
                        published += 1;
                    }
                    None => break,
                }
            }
            published
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        pair.close();
        let published = producer.join().unwrap();
        assert!(published <= 1, "producer kept publishing after close: {published}");
    }

    #[test]
    fn finish_without_publish_unblocks_consumer() {
        let pair = Arc::new(PoolPair::new());
        let p2 = Arc::clone(&pair);
        let h = std::thread::spawn(move || p2.take());
        pair.finish();
        assert!(h.join().unwrap().is_none());
    }

    #[test]
    fn at_most_two_buffers_exist() {
        // producer blocks on the second publish until consumer takes
        let pair = Arc::new(PoolPair::new());
        let p2 = Arc::clone(&pair);
        let producer = std::thread::spawn(move || {
            let mut buf = SamplePool::new();
            for _ in 0..3 {
                buf.push((1, 1));
                buf = p2.publish(buf).expect("consumer alive");
            }
            p2.finish();
        });
        // sleep to let producer try to run ahead — it can't publish #3
        // until we take #1 and recycle.
        std::thread::sleep(std::time::Duration::from_millis(20));
        let mut n = 0;
        while let Some(pool) = pair.take() {
            n += 1;
            pair.recycle(pool);
        }
        assert_eq!(n, 3);
        producer.join().unwrap();
    }
}
