//! Analytic memory model — regenerates the paper's Table 1 and backs the
//! "Limited GPU Memory" sizing decisions (which partition capacity /
//! artifact variant a run needs).

use crate::util::human_bytes;
use crate::util::bench::Table;

/// Memory cost of node embedding on a given network (paper Table 1).
#[derive(Debug, Clone, Copy)]
pub struct MemoryModel {
    pub num_nodes: u64,
    pub num_edges: u64,
    pub dim: u64,
    /// Random-walk length (edges); the augmentation blow-up factor.
    pub walk_length: u64,
    /// Augmentation distance s.
    pub augmentation_distance: u64,
}

impl MemoryModel {
    /// Paper's running example: 50M nodes, 1B edges, d=128, walk length
    /// 40 with full-window (DeepWalk-style) augmentation — every pair on
    /// the walk counts, s = walk length. That yields ~41x |E| directed
    /// samples ≈ 3e10 more than 300 GB, matching the paper's "373 GB /
    /// 5e10 augmented edges" row to within 20%.
    pub fn paper_example() -> Self {
        MemoryModel {
            num_nodes: 50_000_000,
            num_edges: 1_000_000_000,
            dim: 128,
            walk_length: 40,
            augmentation_distance: 40,
        }
    }

    /// Node id storage: 4 bytes per node (u32 ids).
    pub fn nodes_bytes(&self) -> u64 {
        self.num_nodes * 4
    }

    /// Edge list storage: two u32 endpoints per edge.
    pub fn edges_bytes(&self) -> u64 {
        self.num_edges * 8
    }

    /// Number of augmented edge samples per walk-covered edge: each walk
    /// of L edges yields ~L·s pairs (clipped at walk end), i.e. ≈ s× the
    /// walk's edges; the paper's example uses 50× (walk 40 with LINE's
    /// low-degree BFS expansion). We expose the exact clipped count.
    pub fn augmented_edges(&self) -> u64 {
        let l = self.walk_length + 1;
        let s = self.augmentation_distance;
        // Unordered within-distance pairs per walk, clipped at the end;
        // training samples are directed arcs (both (u,v) and (v,u)), so ×2.
        let per_walk: u64 =
            2 * (0..l).map(|i| (i + s).min(l - 1).saturating_sub(i)).sum::<u64>();
        // walks cover each edge once on average when |walks| * L = |E|
        (self.num_edges as f64 * per_walk as f64 / self.walk_length as f64) as u64
    }

    pub fn augmented_bytes(&self) -> u64 {
        self.augmented_edges() * 8
    }

    /// One embedding matrix (vertex or context): |V| × d × f32.
    pub fn matrix_bytes(&self) -> u64 {
        self.num_nodes * self.dim * 4
    }

    /// Per-GPU bytes when partitioned n-ways (vertex + context partition).
    pub fn per_gpu_bytes(&self, num_parts: u64) -> u64 {
        2 * (self.matrix_bytes() / num_parts)
    }

    /// Render the Table 1 layout.
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            "Table 1 — memory cost of node embedding",
            &["component", "size formula", "count", "minimum storage"],
        );
        t.row(&[
            "nodes".into(),
            "|V|".into(),
            format!("{:.1e}", self.num_nodes as f64),
            human_bytes(self.nodes_bytes()),
        ]);
        t.row(&[
            "edges".into(),
            "|E|".into(),
            format!("{:.1e}", self.num_edges as f64),
            human_bytes(self.edges_bytes()),
        ]);
        t.row(&[
            "augmented edges".into(),
            "|E'|".into(),
            format!("{:.1e}", self.augmented_edges() as f64),
            human_bytes(self.augmented_bytes()),
        ]);
        t.row(&[
            "vertex".into(),
            "|V| x d".into(),
            format!("{:.1e} x {}", self.num_nodes as f64, self.dim),
            human_bytes(self.matrix_bytes()),
        ]);
        t.row(&[
            "context".into(),
            "|V| x d".into(),
            format!("{:.1e} x {}", self.num_nodes as f64, self.dim),
            human_bytes(self.matrix_bytes()),
        ]);
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_example_magnitudes() {
        let m = MemoryModel::paper_example();
        // paper: nodes 191 MB, edges 7.45 GB, vertex/context 23.8 GB
        assert_eq!(m.nodes_bytes(), 200_000_000); // 4B/node = 191 MiB
        assert!((m.nodes_bytes() as f64 / (1 << 20) as f64 - 190.7).abs() < 1.0);
        assert!((m.edges_bytes() as f64 / (1u64 << 30) as f64 - 7.45).abs() < 0.1);
        assert!((m.matrix_bytes() as f64 / (1u64 << 30) as f64 - 23.84).abs() < 0.1);
        // augmented edges within the paper's order of magnitude
        // (paper: 5e10 -> 373 GB; full-window walk-40 model: ~41x|E| -> ~305 GiB)
        let aug_gb = m.augmented_bytes() as f64 / (1u64 << 30) as f64;
        assert!((aug_gb - 305.0).abs() < 40.0, "aug {aug_gb} GB");
        // a LINE-style short augmentation distance shrinks E' dramatically
        let line_like = MemoryModel { augmentation_distance: 5, ..m };
        let ll_gb = line_like.augmented_bytes() as f64 / (1u64 << 30) as f64;
        assert!(ll_gb < aug_gb / 3.0, "line-like {ll_gb} GB vs {aug_gb} GB");
    }

    #[test]
    fn per_gpu_shrinks_with_parts() {
        let m = MemoryModel::paper_example();
        assert!(m.per_gpu_bytes(4) < 2 * m.matrix_bytes());
        assert_eq!(m.per_gpu_bytes(1), 2 * m.matrix_bytes());
    }

    #[test]
    fn table_renders() {
        let t = MemoryModel::paper_example().table();
        assert_eq!(t.rows.len(), 5);
        assert!(t.to_markdown().contains("augmented edges"));
    }
}
