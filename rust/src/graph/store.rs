//! The [`GraphStore`] seam: one read-only graph interface implemented by
//! both the in-RAM CSR ([`Graph`](super::Graph)) and the out-of-core
//! paged reader ([`PagedCsr`](super::PagedCsr)), so the sampling stack —
//! random walker, online augmenter, edge sampler, negative sampler,
//! partitioner, stats — trains off either without knowing which.
//!
//! Design constraints:
//!
//! * **Object safety.** The trainer holds `Arc<dyn GraphStore>`; every
//!   method is dyn-compatible (visitor closures instead of generic
//!   iterators, caller-supplied output buffers instead of borrowed
//!   slices).
//! * **O(V) resident, O(E) streamable.** Per-node scalars (degrees,
//!   weighted degrees, labels) are cheap enough to keep in RAM even at
//!   paper scale (66M nodes ≈ 1.2 GB); only the successor lists — the
//!   O(E) part — go through the streaming methods, which an out-of-core
//!   store serves from a bounded page cache.
//! * **Identical observation order.** `successors_into` must yield the
//!   same targets in the same order as the in-RAM adjacency: training off
//!   a packed file is bitwise-identical to training off the loader
//!   (asserted in `rust/tests/ondisk.rs`), because every RNG draw that
//!   depends on a neighbor list sees the same list.
//!
//! Storage errors *after* a successful open (I/O failure, page-level
//! corruption) panic rather than return: the trait keeps infallible
//! signatures so the hot sampling loop stays clean, and a mid-training
//! disk fault is unrecoverable anyway — fail loud, never train on
//! garbage.

use super::Graph;

/// Read-only graph access for the sampling/training stack. Implemented
/// by the in-RAM [`Graph`] and the on-disk [`PagedCsr`](super::PagedCsr).
pub trait GraphStore: Send + Sync {
    /// Number of nodes (dense `u32` ids `0..num_nodes`).
    fn num_nodes(&self) -> usize;

    /// Number of undirected edges.
    fn num_edges(&self) -> usize;

    /// Total adjacency entries (directed arc count = 2 × edges).
    fn num_arcs(&self) -> usize;

    /// Unweighted out-degree of `v`.
    fn degree(&self, v: u32) -> usize;

    /// Weighted degree of `v` (sum of incident weights).
    fn weighted_degree(&self, v: u32) -> f32;

    /// All weighted degrees, indexed by node id (resident; feeds the
    /// departure-node alias table and the negative sampler).
    fn weighted_degrees(&self) -> &[f32];

    /// True if every edge weight is exactly 1.0 (enables the uniform
    /// neighbor-choice fast path — no alias tables).
    fn unit_weights(&self) -> bool;

    /// Community labels, if the graph carries them.
    fn labels(&self) -> Option<&[u16]>;

    /// Borrow `v`'s neighbor list directly when the store is resident.
    /// `None` means the caller must go through [`Self::successors_into`]
    /// (the out-of-core path); in-RAM stores return the slice so the walk
    /// hot loop stays zero-copy.
    fn neighbors_slice(&self, _v: u32) -> Option<&[u32]> {
        None
    }

    /// Borrow `v`'s edge weights (parallel to [`Self::neighbors_slice`])
    /// when the store is resident — the zero-copy counterpart of
    /// [`Self::neighborhood_into`] (the weighted walker builds its alias
    /// tables through this without copying targets it never reads).
    fn neighbor_weights_slice(&self, _v: u32) -> Option<&[f32]> {
        None
    }

    /// Replace `targets` with `v`'s successors, in adjacency order.
    fn successors_into(&self, v: u32, targets: &mut Vec<u32>);

    /// Replace `targets`/`weights` with `v`'s successors and their edge
    /// weights (parallel vectors, adjacency order).
    fn neighborhood_into(&self, v: u32, targets: &mut Vec<u32>, weights: &mut Vec<f32>);

    /// Visit every arc `(source, target, weight)` in node order — the
    /// sequential full scan (edge sampler construction, export). Paged
    /// stores stream this with page-sequential locality.
    fn for_each_arc(&self, f: &mut dyn FnMut(u32, u32, f32));

    /// True when the store carries pre-built per-node alias tables (the
    /// `.gvpk` alias sidecar) that the weighted walker should stream via
    /// [`Self::alias_into`] instead of building O(E) resident tables.
    fn alias_tables_streamed(&self) -> bool {
        false
    }

    /// Replace `prob`/`alias` with node `v`'s alias table (Vose layout,
    /// both of length `degree(v)`). Only meaningful when
    /// [`Self::alias_tables_streamed`] is true and `degree(v) >= 2`; the
    /// bits must equal what [`crate::sampling::AliasTable::new`] builds
    /// from `v`'s weights, so streamed and resident walks draw
    /// identically.
    fn alias_into(&self, v: u32, _prob: &mut Vec<f32>, _alias: &mut Vec<u32>) {
        unreachable!("alias_into on a store without streamed alias tables (node {v})");
    }

    /// External (pre-reorder) node id per internal id, when the store
    /// was packed with a reorder permutation. `None` means internal ids
    /// ARE the external ids. Training output is mapped back through
    /// this so embeddings line up with the original edge-list ids.
    fn external_ids(&self) -> Option<&[u32]> {
        None
    }
}

impl GraphStore for Graph {
    fn num_nodes(&self) -> usize {
        Graph::num_nodes(self)
    }

    fn num_edges(&self) -> usize {
        Graph::num_edges(self)
    }

    fn num_arcs(&self) -> usize {
        Graph::num_arcs(self)
    }

    fn degree(&self, v: u32) -> usize {
        Graph::degree(self, v)
    }

    fn weighted_degree(&self, v: u32) -> f32 {
        Graph::weighted_degree(self, v)
    }

    fn weighted_degrees(&self) -> &[f32] {
        Graph::weighted_degrees(self)
    }

    fn unit_weights(&self) -> bool {
        Graph::unit_weights(self)
    }

    fn labels(&self) -> Option<&[u16]> {
        Graph::labels(self)
    }

    fn neighbors_slice(&self, v: u32) -> Option<&[u32]> {
        Some(self.neighbors(v))
    }

    fn neighbor_weights_slice(&self, v: u32) -> Option<&[f32]> {
        Some(self.neighbor_weights(v))
    }

    fn successors_into(&self, v: u32, targets: &mut Vec<u32>) {
        targets.clear();
        targets.extend_from_slice(self.neighbors(v));
    }

    fn neighborhood_into(&self, v: u32, targets: &mut Vec<u32>, weights: &mut Vec<f32>) {
        targets.clear();
        weights.clear();
        targets.extend_from_slice(self.neighbors(v));
        weights.extend_from_slice(self.neighbor_weights(v));
    }

    fn for_each_arc(&self, f: &mut dyn FnMut(u32, u32, f32)) {
        for (u, v, w) in self.arcs() {
            f(u, v, w);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generators, GraphBuilder};

    #[test]
    fn graph_implements_store_consistently() {
        let g = generators::karate_club();
        let store: &dyn GraphStore = &g;
        assert_eq!(store.num_nodes(), 34);
        assert_eq!(store.num_edges(), 78);
        assert_eq!(store.num_arcs(), 156);
        assert!(store.unit_weights());
        let mut t = Vec::new();
        let mut w = Vec::new();
        for v in 0..34u32 {
            assert_eq!(store.degree(v), g.degree(v));
            assert_eq!(store.neighbors_slice(v), Some(g.neighbors(v)));
            store.successors_into(v, &mut t);
            assert_eq!(t, g.neighbors(v));
            store.neighborhood_into(v, &mut t, &mut w);
            assert_eq!(t, g.neighbors(v));
            assert_eq!(w, g.neighbor_weights(v));
        }
        let mut arcs = 0usize;
        store.for_each_arc(&mut |u, v, wt| {
            assert!(g.has_edge(u, v));
            assert!(wt > 0.0);
            arcs += 1;
        });
        assert_eq!(arcs, 156);
    }

    #[test]
    fn buffers_are_replaced_not_appended() {
        let g = GraphBuilder::new().add_edge(0, 1, 2.0).add_edge(0, 2, 3.0).build();
        let store: &dyn GraphStore = &g;
        let mut t = vec![99u32; 8];
        let mut w = vec![9.0f32; 8];
        store.neighborhood_into(0, &mut t, &mut w);
        assert_eq!(t, vec![1, 2]);
        assert_eq!(w, vec![2.0, 3.0]);
        store.successors_into(1, &mut t);
        assert_eq!(t, vec![0]);
    }
}
