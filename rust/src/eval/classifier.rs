//! One-vs-rest logistic regression over (normalized) node embeddings —
//! the paper's node-classification protocol (§4.4: "train one-vs-rest
//! linear classifiers over the normalized node embeddings"), with
//! micro-/macro-F1 reporting.

use crate::util::rng::Rng;

/// Trained OvR logistic regression: one (w, b) per class.
#[derive(Debug, Clone)]
pub struct LogisticOvR {
    num_classes: usize,
    dim: usize,
    /// weights: `num_classes × dim`, row-major.
    weights: Vec<f32>,
    bias: Vec<f32>,
}

impl LogisticOvR {
    /// Fit with mini-batchless SGD + L2. `features` is row-major `n × dim`
    /// (pass [`crate::embedding::EmbeddingStore::normalized_vertex`]),
    /// `labels[i] < num_classes`, training restricted to `train_ids`.
    #[allow(clippy::too_many_arguments)]
    pub fn fit(
        features: &[f32],
        dim: usize,
        labels: &[u16],
        train_ids: &[u32],
        num_classes: usize,
        epochs: usize,
        lr: f32,
        l2: f32,
        seed: u64,
    ) -> Self {
        assert!(num_classes >= 2);
        let mut model = LogisticOvR {
            num_classes,
            dim,
            weights: vec![0.0; num_classes * dim],
            bias: vec![0.0; num_classes],
        };
        let mut rng = Rng::new(seed);
        let mut order: Vec<u32> = train_ids.to_vec();
        for epoch in 0..epochs {
            rng.shuffle(&mut order);
            let lr_t = lr / (1.0 + epoch as f32 * 0.1);
            for &i in &order {
                let x = &features[i as usize * dim..(i as usize + 1) * dim];
                let y = labels[i as usize] as usize;
                for c in 0..num_classes {
                    let w = &mut model.weights[c * dim..(c + 1) * dim];
                    let z: f32 =
                        w.iter().zip(x).map(|(a, b)| a * b).sum::<f32>() + model.bias[c];
                    let p = 1.0 / (1.0 + (-z).exp());
                    let t = if c == y { 1.0 } else { 0.0 };
                    let g = p - t;
                    for (wj, xj) in w.iter_mut().zip(x) {
                        *wj -= lr_t * (g * xj + l2 * *wj);
                    }
                    model.bias[c] -= lr_t * g;
                }
            }
        }
        model
    }

    /// Predict the argmax class for node features `x`.
    pub fn predict(&self, x: &[f32]) -> u16 {
        let mut best = 0usize;
        let mut best_z = f32::NEG_INFINITY;
        for c in 0..self.num_classes {
            let w = &self.weights[c * self.dim..(c + 1) * self.dim];
            let z: f32 = w.iter().zip(x).map(|(a, b)| a * b).sum::<f32>() + self.bias[c];
            if z > best_z {
                best_z = z;
                best = c;
            }
        }
        best as u16
    }

    /// Evaluate on `test_ids`, returning micro/macro F1.
    pub fn evaluate(
        &self,
        features: &[f32],
        labels: &[u16],
        test_ids: &[u32],
    ) -> NodeClassificationReport {
        let k = self.num_classes;
        let mut tp = vec![0u64; k];
        let mut fp = vec![0u64; k];
        let mut fn_ = vec![0u64; k];
        for &i in test_ids {
            let x = &features[i as usize * self.dim..(i as usize + 1) * self.dim];
            let pred = self.predict(x) as usize;
            let truth = labels[i as usize] as usize;
            if pred == truth {
                tp[truth] += 1;
            } else {
                fp[pred] += 1;
                fn_[truth] += 1;
            }
        }
        NodeClassificationReport::from_counts(&tp, &fp, &fn_)
    }
}

/// Micro/macro-F1 report (the two Table 4 metrics).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NodeClassificationReport {
    pub micro_f1: f64,
    pub macro_f1: f64,
}

impl NodeClassificationReport {
    pub fn from_counts(tp: &[u64], fp: &[u64], fn_: &[u64]) -> Self {
        let k = tp.len();
        // micro: pool all counts. (For single-label multi-class, micro-F1
        // equals accuracy; kept in count form for clarity/extensibility.)
        let (stp, sfp, sfn): (u64, u64, u64) = (
            tp.iter().sum(),
            fp.iter().sum(),
            fn_.iter().sum(),
        );
        let micro = f1(stp, sfp, sfn);
        // macro: average per-class F1 over classes that appear
        let mut macro_sum = 0.0;
        let mut present = 0usize;
        for c in 0..k {
            if tp[c] + fn_[c] == 0 {
                continue; // class absent from test set
            }
            macro_sum += f1(tp[c], fp[c], fn_[c]);
            present += 1;
        }
        NodeClassificationReport {
            micro_f1: micro,
            macro_f1: if present > 0 { macro_sum / present as f64 } else { 0.0 },
        }
    }
}

fn f1(tp: u64, fp: u64, fn_: u64) -> f64 {
    if tp == 0 {
        return 0.0;
    }
    let p = tp as f64 / (tp + fp) as f64;
    let r = tp as f64 / (tp + fn_) as f64;
    2.0 * p * r / (p + r)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Linearly separable 2-class blob data.
    fn blobs(n: usize, dim: usize, seed: u64) -> (Vec<f32>, Vec<u16>) {
        let mut rng = Rng::new(seed);
        let mut x = Vec::with_capacity(n * dim);
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            let c = (i % 2) as u16;
            let center = if c == 0 { -1.0 } else { 1.0 };
            for _ in 0..dim {
                x.push(center + rng.normal() as f32 * 0.3);
            }
            y.push(c);
        }
        (x, y)
    }

    #[test]
    fn separable_blobs_high_f1() {
        let (x, y) = blobs(400, 8, 1);
        let (train, test) = crate::eval::train_test_split(400, 0.5, 2);
        let model = LogisticOvR::fit(&x, 8, &y, &train, 2, 20, 0.5, 1e-4, 3);
        let rep = model.evaluate(&x, &y, &test);
        assert!(rep.micro_f1 > 0.95, "micro {}", rep.micro_f1);
        assert!(rep.macro_f1 > 0.95, "macro {}", rep.macro_f1);
    }

    #[test]
    fn three_class_blobs() {
        // class c centered at angle 2πc/3 in first two dims
        let n = 600;
        let dim = 4;
        let mut rng = Rng::new(4);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for i in 0..n {
            let c = (i % 3) as u16;
            let ang = 2.0 * std::f64::consts::PI * c as f64 / 3.0;
            x.push((ang.cos() * 2.0 + rng.normal() * 0.3) as f32);
            x.push((ang.sin() * 2.0 + rng.normal() * 0.3) as f32);
            for _ in 2..dim {
                x.push(rng.normal() as f32 * 0.1);
            }
            y.push(c);
        }
        let (train, test) = crate::eval::train_test_split(n, 0.3, 5);
        let model = LogisticOvR::fit(&x, dim, &y, &train, 3, 25, 0.5, 1e-4, 6);
        let rep = model.evaluate(&x, &y, &test);
        assert!(rep.micro_f1 > 0.9, "micro {}", rep.micro_f1);
    }

    #[test]
    fn random_labels_near_chance() {
        let mut rng = Rng::new(7);
        let n = 500;
        let dim = 8;
        let x: Vec<f32> = (0..n * dim).map(|_| rng.normal() as f32).collect();
        let y: Vec<u16> = (0..n).map(|_| (rng.below(4)) as u16).collect();
        let (train, test) = train_split(n);
        let model = LogisticOvR::fit(&x, dim, &y, &train, 4, 10, 0.2, 1e-4, 8);
        let rep = model.evaluate(&x, &y, &test);
        assert!(rep.micro_f1 < 0.45, "micro {}", rep.micro_f1); // ~0.25 expected
    }

    fn train_split(n: usize) -> (Vec<u32>, Vec<u32>) {
        crate::eval::train_test_split(n, 0.5, 9)
    }

    #[test]
    fn f1_math() {
        assert_eq!(f1(0, 0, 0), 0.0);
        assert!((f1(10, 0, 0) - 1.0).abs() < 1e-12);
        // p=0.5, r=1.0 -> f1 = 2/3
        assert!((f1(10, 10, 0) - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn perfect_counts_perfect_report() {
        let rep = NodeClassificationReport::from_counts(&[5, 5], &[0, 0], &[0, 0]);
        assert_eq!(rep.micro_f1, 1.0);
        assert_eq!(rep.macro_f1, 1.0);
    }
}
