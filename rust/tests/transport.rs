//! Transport-seam suite — the PR's headline acceptance assertions:
//!
//! 1. **Equivalence.** Training over the loopback socket transport
//!    (real `run_worker` processes-in-threads, real TCP frames, real
//!    handshake) is **bitwise-identical** to the in-process local
//!    transport: same embeddings, same coordinator-side counters, in
//!    pipelined and serial dispatch, homogeneous and heterogeneous
//!    capacities. The episode planner never changes — only delivery.
//! 2. **Ledger.** The payload bytes each side counted crossing the wire
//!    agree connection-by-connection (worker BYE vs. coordinator
//!    counters) and in aggregate with the transfer engine's
//!    `bytes_to_device` / `bytes_from_device`.
//! 3. **Fail loud.** Injected faults (drops, duplicates, reorders,
//!    disconnects — deterministic, seeded, via [`FlakyTransport`]) turn
//!    into pointed errors or bitwise-unchanged runs, never hangs or
//!    silent corruption; a checkpointed run interrupted by a fault
//!    resumes to the exact bytes of the uninterrupted run.
//! 4. **Hostile peers.** Garbage handshakes are rejected without
//!    disturbing the run; a worker dialing a hostile coordinator gets a
//!    pointed error, never a panic.
//! 5. **Failure recovery.** With `max_worker_retries > 0`, a worker
//!    killed mid-group — simulated through [`FlakyTransport`] AND a real
//!    socket drop — is replaced by a rejoining `graphvite worker` (its
//!    journaled jobs replayed verbatim) or folded onto the survivors,
//!    and the final embeddings are **bitwise-identical** to the
//!    fault-free run in pipelined, serial and heterogeneous configs.
//!    When recovery is exhausted, `--fault-checkpoint` cuts a `.gvck` at
//!    the last completed pool boundary that resumes bitwise-identically.

use std::net::TcpListener;
use std::time::Duration;

use graphvite::config::{BackendKind, TrainConfig, WorkerMode};
use graphvite::coordinator::transport::{
    encode_reject, run_worker, run_worker_with_fault, FaultPlan, FlakyTransport, WorkerSummary,
};
use graphvite::coordinator::{
    load_checkpoint, save_checkpoint, CheckpointState, TrainFlow, TrainResult, Trainer,
    TransportReport,
};
use graphvite::graph::{generators, Graph};
use graphvite::net;
use graphvite::pool::ShuffleKind;

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("graphvite_transport_tests");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

/// Deterministic test graph; regenerated wherever a fresh copy is needed
/// (same seed, same bytes).
fn graph() -> Graph {
    generators::barabasi_albert(300, 3, 5)
}

fn cfg(seed: u64) -> TrainConfig {
    TrainConfig {
        dim: 8,
        epochs: 4,
        num_workers: 2,
        num_samplers: 2,
        episode_size: 500,
        batch_size: 64,
        backend: BackendKind::test_backend(),
        shuffle: ShuffleKind::Pseudo,
        seed,
        ..TrainConfig::default()
    }
}

/// The socket transport cannot host the pjrt backend (HLO artifacts are
/// host-local); when CI's backend matrix pins pjrt, the tcp legs skip.
fn tcp_capable() -> bool {
    BackendKind::test_backend() != BackendKind::Pjrt
}

/// Run `cfg` over a loopback socket: bind an ephemeral listener, host
/// every worker in its own thread via the *real* `graphvite worker`
/// body ([`run_worker`] — TCP frames, handshake, BYE ledger and all),
/// and train. Returns the result, the verified wire ledger and each
/// worker's own summary.
fn tcp_run(base: TrainConfig) -> (TrainResult, TransportReport, Vec<WorkerSummary>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let n = base.num_workers;
    let cfg = TrainConfig { worker_mode: WorkerMode::Tcp(addr.clone()), ..base };
    let mut trainer = Trainer::new(graph(), cfg).unwrap();
    trainer.set_worker_listener(listener);
    let workers: Vec<_> = (0..n)
        .map(|_| {
            let addr = addr.clone();
            std::thread::spawn(move || run_worker(&addr, Duration::from_secs(30)))
        })
        .collect();
    let result = trainer.train().unwrap();
    let report = trainer.transport_report().expect("tcp run must produce a wire ledger");
    let summaries: Vec<WorkerSummary> =
        workers.into_iter().map(|h| h.join().unwrap().unwrap()).collect();
    (result, report, summaries)
}

/// Bitwise equivalence of two runs: embeddings and every
/// coordinator-side counter. Device-local counters (`device_steps`,
/// `device_nanos`) are excluded — remote workers keep those in their own
/// process — as are wall-clock timings.
fn assert_equivalent(local: &TrainResult, other: &TrainResult, tag: &str) {
    assert_eq!(
        local.embeddings.vertex_matrix(),
        other.embeddings.vertex_matrix(),
        "{tag}: vertex matrices diverged"
    );
    assert_eq!(
        local.embeddings.context_matrix(),
        other.embeddings.context_matrix(),
        "{tag}: context matrices diverged"
    );
    let (a, b) = (&local.stats.counters, &other.stats.counters);
    assert_eq!(a.samples_generated, b.samples_generated, "{tag}: samples_generated");
    assert_eq!(a.samples_trained, b.samples_trained, "{tag}: samples_trained");
    assert_eq!(a.bytes_to_device, b.bytes_to_device, "{tag}: bytes_to_device");
    assert_eq!(a.bytes_from_device, b.bytes_from_device, "{tag}: bytes_from_device");
    assert_eq!(a.episodes, b.episodes, "{tag}: episodes");
    assert_eq!(a.residency_hits, b.residency_hits, "{tag}: residency_hits");
    assert_eq!(a.bytes_saved, b.bytes_saved, "{tag}: bytes_saved");
}

/// The per-connection ledgers must re-add to the aggregate report, and
/// every worker slot must have been filled exactly once.
fn assert_ledger(report: &TransportReport, summaries: &[WorkerSummary], n: usize) {
    assert_eq!(report.workers, n);
    let mut seen = vec![false; n];
    for s in summaries {
        assert!(!seen[s.worker_index], "worker slot {} assigned twice", s.worker_index);
        seen[s.worker_index] = true;
    }
    let up: u64 = summaries.iter().map(|s| s.bytes_received).sum();
    let down: u64 = summaries.iter().map(|s| s.bytes_sent).sum();
    assert_eq!(up, report.bytes_up, "worker-side received sum vs coordinator sent");
    assert_eq!(down, report.bytes_down, "worker-side sent sum vs coordinator received");
    // the raw-vs-on-wire ledger: both directions agree in aggregate too,
    // and the stored fallback guarantees the wire never exceeds raw
    let wire_up: u64 = summaries.iter().map(|s| s.wire_received).sum();
    let wire_down: u64 = summaries.iter().map(|s| s.wire_sent).sum();
    assert_eq!(wire_up, report.wire_up, "worker-side wire received vs coordinator");
    assert_eq!(wire_down, report.wire_down, "worker-side wire sent vs coordinator");
    assert!(report.wire_up <= report.bytes_up, "wire bytes exceed raw (up)");
    assert!(report.wire_down <= report.bytes_down, "wire bytes exceed raw (down)");
    assert_eq!(
        report.wire_bytes_saved(),
        (report.bytes_up - report.wire_up) + (report.bytes_down - report.wire_down)
    );
}

// ------------------------------------------------ bitwise equivalence --

#[test]
fn loopback_socket_is_bitwise_identical_pipelined() {
    if !tcp_capable() {
        eprintln!("skipping: socket transport cannot host the pjrt backend");
        return;
    }
    let local = Trainer::new(graph(), cfg(9)).unwrap().train().unwrap();
    let (remote, report, summaries) = tcp_run(cfg(9));
    assert_equivalent(&local, &remote, "pipelined");
    assert_ledger(&report, &summaries, 2);
    // the aggregate wire ledger IS the transfer engine's plan
    assert_eq!(report.bytes_up, remote.stats.counters.bytes_to_device);
    assert_eq!(report.bytes_down, remote.stats.counters.bytes_from_device);
    assert!(report.bytes_up > 0, "no payload ever crossed the wire?");
}

#[test]
fn loopback_socket_is_bitwise_identical_serial() {
    if !tcp_capable() {
        eprintln!("skipping: socket transport cannot host the pjrt backend");
        return;
    }
    // no producer thread, no pipelined dispatch: every wave fenced
    let mk = || TrainConfig { collaboration: false, pipeline_transfers: false, ..cfg(23) };
    let local = Trainer::new(graph(), mk()).unwrap().train().unwrap();
    let (remote, report, summaries) = tcp_run(mk());
    assert_equivalent(&local, &remote, "serial");
    assert_ledger(&report, &summaries, 2);
}

#[test]
fn loopback_socket_is_bitwise_identical_heterogeneous() {
    if !tcp_capable() {
        eprintln!("skipping: socket transport cannot host the pjrt backend");
        return;
    }
    // capacities [1, 3]: worker 1 takes 3 blocks per wave with a 3x
    // batch chunk — the assignment must carry capacity-scaled geometry
    let mk = || TrainConfig {
        worker_capacities: vec![1, 3],
        num_partitions: 4,
        fix_context: false,
        ..cfg(41)
    };
    let local = Trainer::new(graph(), mk()).unwrap().train().unwrap();
    let (remote, report, summaries) = tcp_run(mk());
    assert_equivalent(&local, &remote, "heterogeneous");
    assert_ledger(&report, &summaries, 2);
}

#[test]
fn wire_compression_off_is_bitwise_identical_and_ships_raw() {
    if !tcp_capable() {
        eprintln!("skipping: socket transport cannot host the pjrt backend");
        return;
    }
    let local = Trainer::new(graph(), cfg(9)).unwrap().train().unwrap();
    let (compressed, on_report, _) = tcp_run(cfg(9));
    let (raw, report, summaries) =
        tcp_run(TrainConfig { wire_compression: false, ..cfg(9) });
    assert_equivalent(&local, &raw, "compression-off");
    assert_equivalent(&compressed, &raw, "compressed vs raw tcp");
    assert_ledger(&report, &summaries, 2);
    // negotiated off: on-wire bytes ARE the raw payload bytes, per
    // direction, with nothing saved
    assert_eq!(report.wire_up, report.bytes_up);
    assert_eq!(report.wire_down, report.bytes_down);
    assert_eq!(report.wire_bytes_saved(), 0);
    // both modes planned identical raw traffic — compression changes
    // delivery, never the transfer plan
    assert_eq!(report.bytes_up, on_report.bytes_up);
    assert_eq!(report.bytes_down, on_report.bytes_down);
}

#[test]
fn local_runs_have_no_wire_ledger() {
    let mut trainer = Trainer::new(graph(), cfg(7)).unwrap();
    trainer.train().unwrap();
    assert_eq!(trainer.transport_report(), None);
}

// -------------------------------------------------- fault injection --

fn flaky_trainer(seed: u64, plan: FaultPlan) -> Trainer {
    let mut trainer = Trainer::new(graph(), cfg(seed)).unwrap();
    trainer.set_transport_wrapper(Box::new(move |inner| {
        Box::new(FlakyTransport::new(inner, plan.clone()))
    }));
    trainer
}

#[test]
fn dropped_replies_fail_loud_instead_of_hanging() {
    let plan = FaultPlan {
        seed: 11,
        drop_permille: 400,
        timeout: Duration::from_millis(300),
        ..FaultPlan::default()
    };
    let err = flaky_trainer(51, plan).train().unwrap_err().to_string();
    assert!(err.contains("no worker reply within"), "{err}");
}

#[test]
fn duplicated_replies_are_rejected_by_the_in_flight_set() {
    // every training reply delivered twice: the first absorb clears the
    // block from the in-flight set, the duplicate must be a pointed
    // error — never a silent double-scatter
    let plan = FaultPlan { seed: 13, dup_permille: 1000, ..FaultPlan::default() };
    let err = flaky_trainer(52, plan).train().unwrap_err().to_string();
    // the duplicate is caught mid-episode by the in-flight set, or — if
    // it straggles past the last fence — at the sync barrier
    assert!(
        err.contains("not in flight") || err.contains("unexpected job result"),
        "{err}"
    );
}

#[test]
fn injected_disconnect_fails_loud_and_cleans_up() {
    let plan =
        FaultPlan { seed: 17, disconnect_after_sends: Some(20), ..FaultPlan::default() };
    let err = flaky_trainer(53, plan).train().unwrap_err().to_string();
    assert!(err.contains("connection lost"), "{err}");
    // reaching here at all proves cleanup: the workers were stopped and
    // joined even though the transport reported a dead connection
}

#[test]
fn reordered_replies_leave_the_trajectory_bitwise_unchanged() {
    // holds delay ~1/4 of training replies behind their successors.
    // Orthogonal-block scatters commute, so absorb order must not
    // change a single bit of the result.
    let clean = Trainer::new(graph(), cfg(54)).unwrap().train().unwrap();
    let plan = FaultPlan { seed: 19, hold_permille: 250, ..FaultPlan::default() };
    let reordered = flaky_trainer(54, plan).train().unwrap();
    assert_equivalent(&clean, &reordered, "reordered");
}

#[test]
fn checkpoint_resume_after_a_fault_is_bitwise_identical() {
    let full = Trainer::new(graph(), cfg(73)).unwrap().train().unwrap();

    // phase 1: checkpoint at the pool-2 boundary (clean transport)
    let ck_path = tmp("fault_resume.gvck");
    let mut trainer = Trainer::new(graph(), cfg(73)).unwrap();
    let mut observer = |state: &CheckpointState<'_>| -> anyhow::Result<TrainFlow> {
        if state.pools_done >= 2 {
            save_checkpoint(state, &ck_path)?;
            return Ok(TrainFlow::Stop);
        }
        Ok(TrainFlow::Continue)
    };
    trainer.train_resumable(None, Some(&mut observer)).unwrap();

    // phase 2: a resume attempt dies on an injected disconnect
    let plan = FaultPlan { seed: 29, disconnect_after_sends: Some(5), ..FaultPlan::default() };
    let mut crashed = flaky_trainer(73, plan);
    let err = crashed
        .train_resumable(Some(load_checkpoint(&ck_path).unwrap()), None)
        .unwrap_err()
        .to_string();
    assert!(err.contains("connection lost"), "{err}");

    // phase 3: the checkpoint is untouched by the failed attempt — a
    // clean resume still lands on the exact bytes of the straight run
    let resumed = Trainer::new(graph(), cfg(73))
        .unwrap()
        .train_resumable(Some(load_checkpoint(&ck_path).unwrap()), None)
        .unwrap();
    assert_eq!(full.embeddings.vertex_matrix(), resumed.embeddings.vertex_matrix());
    assert_eq!(full.embeddings.context_matrix(), resumed.embeddings.context_matrix());
}

// ------------------------------------------------- failure recovery --

/// `base` with the recovery budget armed: one worker failure is
/// recovered (rejoin or fold) instead of killing the run.
fn recovery_cfg(base: TrainConfig) -> TrainConfig {
    TrainConfig { max_worker_retries: 1, ..base }
}

/// Embedding-only equivalence for recovery runs. Bus counters are *not*
/// compared: a recovered run legitimately ships extra payload (journal
/// replays, fold gathers, per-group fence syncs), but the trained
/// trajectory — every f32 of both matrices and the sample counts — must
/// not move by a single bit.
fn assert_same_trajectory(clean: &TrainResult, recovered: &TrainResult, tag: &str) {
    assert_eq!(
        clean.embeddings.vertex_matrix(),
        recovered.embeddings.vertex_matrix(),
        "{tag}: vertex matrices diverged"
    );
    assert_eq!(
        clean.embeddings.context_matrix(),
        recovered.embeddings.context_matrix(),
        "{tag}: context matrices diverged"
    );
    let (a, b) = (&clean.stats.counters, &recovered.stats.counters);
    assert_eq!(a.samples_generated, b.samples_generated, "{tag}: samples_generated");
    assert_eq!(a.samples_trained, b.samples_trained, "{tag}: samples_trained");
}

/// Kill worker 1 mid-run through the fault harness (no process to
/// rejoin, so the slot folds onto worker 0) and demand the fault-free
/// bytes.
fn fold_run(base: TrainConfig, tag: &str) {
    let clean = Trainer::new(graph(), base.clone()).unwrap().train().unwrap();
    let plan = FaultPlan {
        seed: 31,
        kill_worker: Some((10, 1)),
        timeout: Duration::from_secs(1),
        ..FaultPlan::default()
    };
    let mut trainer = Trainer::new(graph(), recovery_cfg(base)).unwrap();
    trainer.set_transport_wrapper(Box::new(move |inner| {
        Box::new(FlakyTransport::new(inner, plan.clone()))
    }));
    let folded = trainer.train().unwrap();
    assert_same_trajectory(&clean, &folded, tag);
}

#[test]
fn killed_worker_folds_onto_survivors_bitwise_pipelined() {
    fold_run(cfg(61), "fold-pipelined");
}

#[test]
fn killed_worker_folds_onto_survivors_bitwise_serial() {
    fold_run(
        TrainConfig { collaboration: false, pipeline_transfers: false, ..cfg(62) },
        "fold-serial",
    );
}

#[test]
fn killed_worker_folds_onto_survivors_bitwise_heterogeneous() {
    fold_run(
        TrainConfig {
            worker_capacities: vec![1, 3],
            num_partitions: 4,
            fix_context: false,
            ..cfg(63)
        },
        "fold-heterogeneous",
    );
}

/// Kill one real socket worker mid-run and let a freshly dialed
/// replacement rejoin the dead slot; the journaled jobs replay (re-coded
/// against the replacement's actual resident state when compression is
/// on) and the trajectory must be the fault-free one, bit for bit.
fn rejoin_run(base: TrainConfig, tag: &str) {
    let clean = Trainer::new(graph(), base.clone()).unwrap().train().unwrap();

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let tcp_cfg = TrainConfig {
        worker_mode: WorkerMode::Tcp(addr.clone()),
        rejoin_window_secs: 30,
        heartbeat_secs: 1,
        ..recovery_cfg(base)
    };
    let mut trainer = Trainer::new(graph(), tcp_cfg).unwrap();
    trainer.set_worker_listener(listener);

    // two initial workers, one of which drops its stream after two jobs —
    // exactly what `kill -9` looks like from the coordinator
    let healthy = {
        let addr = addr.clone();
        std::thread::spawn(move || run_worker(&addr, Duration::from_secs(30)))
    };
    let doomed = {
        let addr = addr.clone();
        std::thread::spawn(move || {
            run_worker_with_fault(&addr, Duration::from_secs(30), Some(2))
        })
    };
    // a replacement and a straggler dial in while the run is live: the
    // first refills the dead slot, the second is turned away (pointed
    // reject if it lands in the same rejoin poll, otherwise the listener
    // going down resets it — never a hang, never a second refill)
    let spares: Vec<_> = [500u64, 700]
        .into_iter()
        .map(|delay_ms| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(delay_ms));
                run_worker(&addr, Duration::from_secs(30))
            })
        })
        .collect();

    let recovered = trainer.train().unwrap();
    let report = trainer.transport_report().expect("tcp run must produce a wire ledger");

    assert_same_trajectory(&clean, &recovered, tag);
    // shutdown() already asserted the per-connection ledgers (BYE vs
    // coordinator counters for every live generation, replacement
    // included); the aggregate also folds in the retired generation's
    // partial traffic, so only its existence is asserted here
    assert_eq!(report.workers, 2);
    assert!(report.bytes_up > 0, "no payload ever crossed the wire?");

    healthy.join().unwrap().unwrap();
    let crash = doomed.join().unwrap().expect_err("the doomed worker must crash");
    assert!(format!("{crash:#}").contains("injected crash"), "{crash:#}");
    let outcomes: Vec<_> = spares.into_iter().map(|h| h.join().unwrap()).collect();
    let refills = outcomes.iter().filter(|o| o.is_ok()).count();
    assert_eq!(refills, 1, "exactly one spare may refill the dead slot: {outcomes:?}");
    let stale = outcomes.iter().find(|o| o.is_err()).unwrap().as_ref().unwrap_err();
    let msg = format!("{stale:#}");
    assert!(
        msg.contains("already refilled")
            || msg.contains("rejected")
            || msg.contains("assignment")
            || msg.contains("connection"),
        "stale worker should get a pointed error, got: {msg}"
    );
}

#[test]
fn crashed_socket_worker_is_replaced_by_a_rejoin_bitwise() {
    if !tcp_capable() {
        eprintln!("skipping: socket transport cannot host the pjrt backend");
        return;
    }
    rejoin_run(cfg(67), "rejoin");
}

#[test]
fn crashed_socket_worker_rejoin_is_bitwise_with_compression_off() {
    if !tcp_capable() {
        eprintln!("skipping: socket transport cannot host the pjrt backend");
        return;
    }
    rejoin_run(TrainConfig { wire_compression: false, ..cfg(67) }, "rejoin-raw");
}

#[test]
fn exhausted_recovery_cuts_a_fault_checkpoint_that_resumes_bitwise() {
    let base = cfg(71);
    let clean = Trainer::new(graph(), base.clone()).unwrap().train().unwrap();

    // worker 1 dies (budget spent on the fold), then the whole transport
    // goes dark — recovery has nothing left, the run must die loudly but
    // leave a resumable checkpoint at the last completed pool boundary
    let ck_path = tmp("fault_cut.gvck");
    let _ = std::fs::remove_file(&ck_path);
    let plan = FaultPlan {
        seed: 37,
        kill_worker: Some((10, 1)),
        disconnect_after_sends: Some(60),
        timeout: Duration::from_secs(1),
        ..FaultPlan::default()
    };
    let mut trainer = Trainer::new(graph(), recovery_cfg(base.clone())).unwrap();
    trainer.set_transport_wrapper(Box::new(move |inner| {
        Box::new(FlakyTransport::new(inner, plan.clone()))
    }));
    trainer.set_fault_checkpoint(&ck_path);
    let err = trainer.train().unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("connection lost"), "{msg}");

    let ck = load_checkpoint(&ck_path).expect("fault checkpoint must exist");
    let resumed = Trainer::new(graph(), base)
        .unwrap()
        .train_resumable(Some(ck), None)
        .unwrap();
    assert_eq!(
        clean.embeddings.vertex_matrix(),
        resumed.embeddings.vertex_matrix(),
        "resume from the fault checkpoint diverged (vertex)"
    );
    assert_eq!(
        clean.embeddings.context_matrix(),
        resumed.embeddings.context_matrix(),
        "resume from the fault checkpoint diverged (context)"
    );
}

// ------------------------------------------------------ hostile peers --

#[test]
fn garbage_handshakes_are_rejected_and_the_run_completes() {
    if !tcp_capable() {
        eprintln!("skipping: socket transport cannot host the pjrt backend");
        return;
    }
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();

    // two hostile peers queue up in the accept backlog BEFORE any real
    // worker: one sends a garbage hello, one hangs up without a word.
    // Both must be rejected without consuming a worker slot.
    {
        use std::io::Write;
        let mut bad = std::net::TcpStream::connect(&addr).unwrap();
        let junk = b"XXXXJUNKJUNK";
        bad.write_all(&(junk.len() as u32).to_le_bytes()).unwrap();
        bad.write_all(junk).unwrap();
        // closed by drop: the reject frame the coordinator writes back
        // is allowed to land on a dead socket
    }
    drop(std::net::TcpStream::connect(&addr).unwrap());

    let n = 2usize;
    let tcp_cfg = TrainConfig { worker_mode: WorkerMode::Tcp(addr.clone()), ..cfg(9) };
    let mut trainer = Trainer::new(graph(), tcp_cfg).unwrap();
    trainer.set_worker_listener(listener);
    let workers: Vec<_> = (0..n)
        .map(|_| {
            let addr = addr.clone();
            std::thread::spawn(move || run_worker(&addr, Duration::from_secs(30)))
        })
        .collect();
    let remote = trainer.train().unwrap();
    for h in workers {
        h.join().unwrap().unwrap();
    }
    // the run behind the hostile peers is still the bitwise run
    let local = Trainer::new(graph(), cfg(9)).unwrap().train().unwrap();
    assert_equivalent(&local, &remote, "post-gauntlet");
}

#[test]
fn worker_dialing_a_rejecting_coordinator_gets_a_pointed_error() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let server = std::thread::spawn(move || {
        let (mut stream, _) = listener.accept().unwrap();
        // read the hello, then turn the worker away
        net::read_frame(&mut stream, 1 << 20).unwrap().unwrap();
        net::write_frame(&mut stream, &encode_reject("all slots are taken"), 1 << 20).unwrap();
    });
    let err = format!("{:#}", run_worker(&addr, Duration::from_secs(10)).unwrap_err());
    assert!(err.contains("rejected"), "{err}");
    assert!(err.contains("all slots are taken"), "{err}");
    server.join().unwrap();
}

#[test]
fn worker_dialing_a_garbage_coordinator_gets_a_pointed_error() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let server = std::thread::spawn(move || {
        let (mut stream, _) = listener.accept().unwrap();
        net::read_frame(&mut stream, 1 << 20).unwrap().unwrap();
        // an "assignment" that is pure junk — the worker must refuse it
        net::write_frame(&mut stream, b"\x00GARBAGE-ASSIGNMENT", 1 << 30).unwrap();
        // the worker answers with a READY-err frame before bailing
        let ready = net::read_frame(&mut stream, 1 << 20).unwrap();
        assert!(ready.is_some(), "worker should explain its refusal");
    });
    let err = format!("{:#}", run_worker(&addr, Duration::from_secs(10)).unwrap_err());
    assert!(err.contains("assignment"), "{err}");
    server.join().unwrap();
}

#[test]
fn worker_dialing_a_dead_address_times_out_with_context() {
    // a port nothing listens on: bind + drop to find a free one
    let addr = {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().to_string()
    };
    let err = run_worker(&addr, Duration::from_millis(300)).unwrap_err().to_string();
    assert!(err.contains("could not connect"), "{err}");
}
