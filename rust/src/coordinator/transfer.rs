//! Host side of the pipelined transfer engine: partition residency
//! planning and the zero-realloc buffer free-lists.
//!
//! The coordinator walks the episode schedule in a fixed dispatch order
//! (the same order every pool pass — [`EpisodeSchedule::execution_sequence`]).
//! That makes data movement *plannable*: for every block dispatch the
//! engine knows which worker touches each partition **next**, so it can
//! decide, deterministically and ahead of time,
//!
//! * **upload elision** — skip gathering/shipping a partition whose
//!   current version is already resident on the target worker (counted in
//!   `residency_hits` / `bytes_saved`), and
//! * **download elision** — tell the worker to keep the trained partition
//!   resident (`Shipment::keep`) exactly when the partition's next block
//!   runs on that same worker, so the buffer never crosses the bus at all.
//!
//! Correctness rests on two invariants. (1) *Versioning*: every touch of
//! a partition bumps its version; a worker may only train on a resident
//! copy whose version matches the coordinator's record (the worker
//! verifies this and fails loudly — no silent stale training). (2)
//! *Single holder*: `keep` is only set when the next toucher is the same
//! worker, so at any fence at most one worker holds a given partition and
//! that copy is the newest. Host-side staleness is repaired at sync
//! fences (the worker protocol's `JobMsg::Sync`): checkpoints and the
//! end of training pull clones of all resident partitions back into the
//! store.
//!
//! With `residency = false` the engine reproduces the PR-2 transfer
//! pattern exactly (everything re-shipped per episode, except the §3.4
//! `fix_context` context pinning), which is what the counter-based
//! regression test in `rust/tests/pipeline_equivalence.rs` compares
//! against.
//!
//! The free-lists close the zero-realloc loop: gather buffers come from
//! `f32_spare` (fed by scattered results), block buffers return from
//! workers through `block_spare` into
//! [`BlockGrid::refill`](crate::pool::BlockGrid::refill), and the drained
//! sample pool itself is recycled through the
//! [`PoolPair`](crate::pool::PoolPair).

use crate::embedding::Matrix;
use crate::scheduler::{Assignment, EpisodeSchedule};

/// The engine's decision for one partition transfer of one job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShipPlan {
    /// Gather + ship the partition (false = residency hit, upload elided).
    pub upload: bool,
    /// Worker keeps the trained buffer resident instead of returning it.
    pub keep: bool,
    /// Version of the copy the worker trains on (its output is
    /// `src_version + 1`).
    pub src_version: u64,
}

/// Deterministic residency planner + buffer free-lists (one per training
/// run, owned by the coordinator's episode loop).
#[derive(Debug)]
pub struct TransferEngine {
    num_parts: usize,
    residency: bool,
    legacy_fix_context: bool,
    /// Current (newest) version per partition; index = `idx(matrix, pid)`.
    latest: Vec<u64>,
    /// resident[worker][idx] = version that worker holds, if any.
    resident: Vec<Vec<Option<u64>>>,
    /// Worker that touches the dispatched assignment's *vertex* partition
    /// next (cyclically, the schedule repeats every pass), per dispatch
    /// slot of one pass.
    next_worker_v: Vec<usize>,
    /// Same for the context partition.
    next_worker_c: Vec<usize>,
    cursor: usize,
    /// Recycled gather/result buffers (padded partition rows).
    pub f32_spare: Vec<Vec<f32>>,
    /// Recycled block buffers, fed back into `BlockGrid::refill`.
    pub block_spare: Vec<Vec<(i32, i32)>>,
}

impl TransferEngine {
    pub fn new(
        sched: &EpisodeSchedule,
        num_workers: usize,
        residency: bool,
        fix_context: bool,
    ) -> Self {
        let seq = sched.execution_sequence();
        let p = sched.num_parts();
        let mut next_worker_v = vec![0usize; seq.len()];
        let mut next_worker_c = vec![0usize; seq.len()];
        let fill = |next: &mut Vec<usize>, part_of: &dyn Fn(&Assignment) -> usize| {
            for pid in 0..p {
                let touches: Vec<usize> = seq
                    .iter()
                    .enumerate()
                    .filter(|(_, a)| part_of(a) == pid)
                    .map(|(t, _)| t)
                    .collect();
                for (k, &t) in touches.iter().enumerate() {
                    let succ = touches[(k + 1) % touches.len()];
                    next[t] = seq[succ].worker;
                }
            }
        };
        fill(&mut next_worker_v, &|a| a.vid);
        fill(&mut next_worker_c, &|a| a.cid);
        TransferEngine {
            num_parts: p,
            residency,
            legacy_fix_context: !residency && fix_context,
            latest: vec![0; 2 * p],
            resident: vec![vec![None; 2 * p]; num_workers],
            next_worker_v,
            next_worker_c,
            cursor: 0,
            f32_spare: Vec::new(),
            block_spare: Vec::new(),
        }
    }

    #[inline]
    fn idx(&self, matrix: Matrix, pid: usize) -> usize {
        match matrix {
            Matrix::Vertex => pid,
            Matrix::Context => self.num_parts + pid,
        }
    }

    /// Plan the (vertex, context) transfers of the next assignment in
    /// dispatch order. Must be called exactly once per dispatched job, in
    /// schedule order — the cursor tracks the position in the pass.
    pub fn plan(&mut self, a: &Assignment) -> (ShipPlan, ShipPlan) {
        let t = self.cursor;
        self.cursor = (self.cursor + 1) % self.next_worker_v.len();
        let next_v = self.next_worker_v[t];
        let next_c = self.next_worker_c[t];
        let v = self.plan_part(Matrix::Vertex, a.vid, a.worker, next_v);
        let c = self.plan_part(Matrix::Context, a.cid, a.worker, next_c);
        (v, c)
    }

    fn plan_part(
        &mut self,
        matrix: Matrix,
        pid: usize,
        worker: usize,
        next_worker: usize,
    ) -> ShipPlan {
        let i = self.idx(matrix, pid);
        let cur = self.latest[i];
        let upload = self.resident[worker][i] != Some(cur);
        let keep = if self.residency {
            next_worker == worker
        } else {
            // PR-2 semantics: only the §3.4 context cache pins anything
            matrix == Matrix::Context && self.legacy_fix_context
        };
        self.latest[i] = cur + 1;
        self.resident[worker][i] = if keep { Some(cur + 1) } else { None };
        ShipPlan { upload, keep, src_version: cur }
    }

    /// Take a recycled f32 buffer for a partition gather.
    pub fn take_f32(&mut self) -> Vec<f32> {
        self.f32_spare.pop().unwrap_or_default()
    }

    /// Return a scattered result buffer to the free-list.
    pub fn put_f32(&mut self, buf: Vec<f32>) {
        self.f32_spare.push(buf);
    }

    /// Return a spent block buffer to the free-list (fed to
    /// `BlockGrid::refill` on the next pool pass).
    pub fn put_block(&mut self, mut block: Vec<(i32, i32)>) {
        block.clear();
        self.block_spare.push(block);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drive `passes` full pool passes through an engine, returning the
    /// per-pass count of uploads (vertex + context).
    fn uploads_per_pass(
        sched: &EpisodeSchedule,
        num_workers: usize,
        residency: bool,
        fix_context: bool,
        passes: usize,
    ) -> Vec<usize> {
        let mut engine = TransferEngine::new(sched, num_workers, residency, fix_context);
        let seq = sched.execution_sequence();
        (0..passes)
            .map(|_| {
                seq.iter()
                    .map(|a| {
                        let (v, c) = engine.plan(a);
                        usize::from(v.upload) + usize::from(c.upload)
                    })
                    .sum()
            })
            .collect()
    }

    #[test]
    fn no_residency_ships_everything_every_pass() {
        let sched = EpisodeSchedule::new(4, 2, false);
        // 16 assignments per pass, 2 uploads each
        assert_eq!(uploads_per_pass(&sched, 2, false, false, 3), vec![32, 32, 32]);
    }

    #[test]
    fn legacy_fix_context_uploads_context_once() {
        let sched = EpisodeSchedule::new(2, 2, true);
        // per pass: 4 assignments; vertex always shipped (4); context
        // shipped only on first-ever touch (2 in pass one, 0 after)
        assert_eq!(uploads_per_pass(&sched, 2, false, true, 3), vec![6, 4, 4]);
    }

    #[test]
    fn residency_order_halves_context_and_pins_vertex() {
        let sched = EpisodeSchedule::new(4, 2, false).with_residency_order();
        // Vertex partitions are sticky to workers under the standard
        // schedule (vid = slot): 4 first-touch uploads in pass one, 0
        // after. Context partitions re-upload only at the 2 residue-class
        // boundaries per pass: 8 context uploads per pass (vs 16).
        assert_eq!(uploads_per_pass(&sched, 2, true, false, 3), vec![12, 8, 8]);
    }

    #[test]
    fn keep_is_only_set_for_same_worker_successor() {
        let sched = EpisodeSchedule::new(4, 2, false).with_residency_order();
        let mut engine = TransferEngine::new(&sched, 2, true, false);
        let seq = sched.execution_sequence();
        // simulate worker caches and verify the single-holder invariant
        let mut holder: Vec<Option<usize>> = vec![None; 8]; // (matrix, pid)
        for pass in 0..2 {
            for a in &seq {
                let (v, c) = engine.plan(a);
                for (plan, idx) in [(v, a.vid), (c, 4 + a.cid)] {
                    if !plan.upload {
                        assert_eq!(
                            holder[idx],
                            Some(a.worker),
                            "pass {pass}: elided upload but worker {} does not hold {idx}",
                            a.worker
                        );
                    }
                    holder[idx] = plan.keep.then_some(a.worker);
                }
            }
        }
    }

    #[test]
    fn free_lists_recycle() {
        let sched = EpisodeSchedule::new(2, 2, false);
        let mut engine = TransferEngine::new(&sched, 2, true, false);
        assert!(engine.take_f32().is_empty());
        let mut buf = engine.take_f32();
        buf.resize(128, 1.0);
        engine.put_f32(buf);
        assert!(engine.take_f32().capacity() >= 128);
        engine.put_block(vec![(1, 2), (3, 4)]);
        let b = engine.block_spare.pop().unwrap();
        assert!(b.is_empty() && b.capacity() >= 2, "cleared but capacity kept");
    }
}
