//! Regenerates paper Table 3 — training time of LINE, DeepWalk, mini-batch-GPU and GraphVite (1 and 4 workers) on the YouTube substitute.
//!
//! Run with `cargo bench --bench bench_table3`; set
//! GRAPHVITE_BENCH_SCALE=tiny|small|full to change the workload size
//! (default tiny so `cargo bench` completes quickly; EXPERIMENTS.md
//! records the `small` runs).

fn scale() -> graphvite::experiments::Scale {
    std::env::var("GRAPHVITE_BENCH_SCALE")
        .ok()
        .and_then(|s| graphvite::experiments::Scale::parse(&s))
        .unwrap_or(graphvite::experiments::Scale::Tiny)
}

fn main() {
    graphvite::experiments::run("table3", scale()).expect("table3 experiment");
}
