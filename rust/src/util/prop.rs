//! Miniature property-testing framework (proptest is not in the offline
//! crate set). Provides seeded case generation with failure reporting of
//! the offending seed, plus common generators for graphs/index vectors.
//!
//! Usage:
//! ```
//! use graphvite::util::prop::{forall, Gen};
//! forall("reverse twice is identity", 100, |g: &mut Gen| {
//!     let xs = g.vec_u32(0..200, 0..1000);
//!     let mut ys = xs.clone();
//!     ys.reverse();
//!     ys.reverse();
//!     assert_eq!(xs, ys);
//! });
//! ```

use crate::util::rng::Rng;
use std::ops::Range;

/// Per-case generator handle wrapping a seeded RNG.
pub struct Gen {
    pub rng: Rng,
    pub case: usize,
}

impl Gen {
    pub fn usize_in(&mut self, r: Range<usize>) -> usize {
        assert!(r.end > r.start);
        r.start + self.rng.below_usize(r.end - r.start)
    }

    pub fn u32_in(&mut self, r: Range<u32>) -> u32 {
        self.usize_in(r.start as usize..r.end as usize) as u32
    }

    pub fn f32_in(&mut self, r: Range<f32>) -> f32 {
        self.rng.range_f32(r.start, r.end)
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.bool(p)
    }

    /// Vector of random u32 with random length in `len` and values in `val`.
    pub fn vec_u32(&mut self, len: Range<usize>, val: Range<u32>) -> Vec<u32> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.u32_in(val.clone())).collect()
    }

    /// Vector of random f32 values.
    pub fn vec_f32(&mut self, len: Range<usize>, val: Range<f32>) -> Vec<f32> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.f32_in(val.clone())).collect()
    }

    /// Random undirected edge list over `n` nodes (no self loops).
    pub fn edges(&mut self, n: usize, max_edges: usize) -> Vec<(u32, u32)> {
        assert!(n >= 2);
        let m = self.usize_in(1..max_edges.max(2));
        (0..m)
            .map(|_| {
                let u = self.rng.below_usize(n) as u32;
                let mut v = self.rng.below_usize(n) as u32;
                while v == u {
                    v = self.rng.below_usize(n) as u32;
                }
                (u, v)
            })
            .collect()
    }

    /// Choose one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below_usize(xs.len())]
    }
}

/// Run `cases` random cases of `body`, panicking with the failing seed.
///
/// The base seed comes from `GRAPHVITE_PROP_SEED` (env) or a fixed default
/// so CI runs are reproducible; set the env var to replay a failure.
pub fn forall(name: &str, cases: usize, body: impl Fn(&mut Gen)) {
    let base: u64 = std::env::var("GRAPHVITE_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x9E3779B9);
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x2545F4914F6CDD1D);
        let mut g = Gen { rng: Rng::new(seed), case };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| body(&mut g)));
        if let Err(e) = result {
            eprintln!(
                "property '{name}' failed at case {case} (replay with \
                 GRAPHVITE_PROP_SEED={base} and case index {case})"
            );
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_runs_all_cases() {
        forall("count", 25, |_g| {});
        forall("ranges", 25, |g| {
            let x = g.usize_in(3..10);
            assert!((3..10).contains(&x));
        });
    }

    #[test]
    #[should_panic]
    fn forall_propagates_failure() {
        forall("fails", 5, |g| {
            assert!(g.usize_in(0..10) > 100);
        });
    }

    #[test]
    fn edges_have_no_self_loops() {
        forall("no self loops", 50, |g| {
            for (u, v) in g.edges(10, 50) {
                assert_ne!(u, v);
                assert!(u < 10 && v < 10);
            }
        });
    }
}
