//! Minimal, API-compatible stand-in for the `anyhow` crate.
//!
//! This build runs with no network access and no pre-populated cargo
//! registry, so the real `anyhow` cannot be fetched. This shim implements
//! exactly the surface the workspace uses:
//!
//! * [`Error`] / [`Result`] with `?`-conversion from any
//!   `std::error::Error + Send + Sync + 'static`
//! * [`anyhow!`], [`bail!`], [`ensure!`]
//! * the [`Context`] extension trait (`context` / `with_context`)
//! * `Display` prints the outermost message; `{:#}` prints the full
//!   `outer: inner: root` chain; `Debug` prints the message plus a
//!   "Caused by:" list (same shapes the real crate renders)
//!
//! Downcasting and backtraces are intentionally out of scope.

use std::fmt;

/// A dynamic error: an ordered context chain, outermost message first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The outermost message.
    pub fn to_string_outer(&self) -> &str {
        &self.chain[0]
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// No `impl std::error::Error for Error` — exactly like the real anyhow,
// which is what keeps this From impl coherent with `impl From<T> for T`.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Self {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(cause) = source {
            chain.push(cause.to_string());
            source = cause.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` — `Result` defaulting the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to results.
pub trait Context<T> {
    fn context<C>(self, context: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: Into<Error>,
{
    fn context<C>(self, context: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C>(self, context: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(e.to_string(), "missing file");
    }

    #[test]
    fn context_chains_and_alternate_display() {
        let res: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = res
            .context("open config")
            .map_err(|e| e.context("load settings"))
            .unwrap_err();
        assert_eq!(format!("{e}"), "load settings");
        assert_eq!(format!("{e:#}"), "load settings: open config: missing file");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("missing file"));
    }

    #[test]
    fn with_context_is_lazy() {
        let ok: std::result::Result<u32, std::io::Error> = Ok(7);
        let v = ok
            .with_context(|| -> String { panic!("must not be called on Ok") })
            .unwrap();
        assert_eq!(v, 7);
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        assert_eq!(none.context("empty").unwrap_err().to_string(), "empty");
        assert_eq!(Some(3u32).context("unused").unwrap(), 3);
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x > 1, "x too small: {x}");
            if x > 10 {
                bail!("x too big: {}", x);
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(f(0).unwrap_err().to_string(), "x too small: 0");
        assert_eq!(f(11).unwrap_err().to_string(), "x too big: 11");
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
        let e = anyhow!("formatted {}", 3);
        assert_eq!(e.to_string(), "formatted 3");
    }
}
