//! The GraphVite coordinator: ties parallel online augmentation (CPU
//! sampler threads), the double-buffered sample-pool pair, the episode
//! scheduler and the device workers into the paper's full hybrid system
//! (Figure 1 / Algorithm 3).
//!
//! Thread topology during [`Trainer::train`]:
//!
//! ```text
//!   producer thread ──  fills pool (num_samplers sampler threads)
//!        │ PoolPair (double buffer, §3.3 collaboration strategy)
//!        ▼
//!   main thread      ── redistribute pool into n×n BlockGrid,
//!                       per episode group: gather partitions, send Jobs
//!        │ mpsc per worker            ▲ results channel
//!        ▼                            │
//!   worker threads   ── one per simulated GPU; owns a gpu::Backend
//!                       (PJRT client+executable or native trainer),
//!                       draws restricted negatives, trains its block
//! ```
//!
//! The coordinator is backend-agnostic: workers construct whatever
//! [`crate::gpu::Backend`] the config selects (`native`, `simd`, or
//! `pjrt`) on their own threads, and the only backend-specific fact the
//! coordinator consumes is the partition padding rule
//! ([`crate::gpu::planned_capacity`]). Swapping kernels — e.g. the
//! f32x8-unrolled [`crate::gpu::SimdWorker`] — changes nothing here.
//!
//! Episode semantics (what the `episodes` counter and `log_every` lines
//! count): one *episode* = one orthogonal group — for `P` partitions, the
//! `P` blocks of a latin-square diagonal from
//! [`crate::scheduler::EpisodeSchedule`], run as `P / n` waves of `n`
//! concurrently-training workers with no shared rows, hence no
//! synchronization — totalling `episode_size` positive samples; one
//! *pool pass* = `P` episodes covering all P² blocks, after which the
//! double-buffered pool pair swaps. The learning rate decays linearly
//! over total samples, matching the paper's SGD schedule.
//!
//! Ablation flags in [`TrainConfig`](crate::config::TrainConfig) switch
//! off each paper component: `online_augmentation` (plain edge sampling
//! instead), `collaboration` (fill and train sequentially), `fix_context`
//! (transfer context partitions every episode) — these drive Table 6.

mod worker;

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::config::{BackendKind, TrainConfig};
use crate::embedding::{EmbeddingStore, Matrix};
use crate::graph::Graph;
use crate::metrics::{Counters, TrainStats};
use crate::partition::Partitioner;
use crate::pool::{BlockGrid, PoolPair, SamplePool};
use crate::pool::shuffle;
use crate::runtime::ArtifactMeta;
use crate::sampling::{AugmentConfig, EdgeSampler, NegativeSampler, OnlineAugmenter, RandomWalker};
use crate::scheduler::EpisodeSchedule;
use crate::util::rng::Rng;
use crate::util::timer::Stopwatch;

use worker::{spawn_workers, Job, JobMsg, JobResult};

/// Output of a training run.
#[derive(Debug)]
pub struct TrainResult {
    pub embeddings: EmbeddingStore,
    pub stats: TrainStats,
}

/// Checkpoint callback: (positive samples trained so far, current store).
pub type Checkpoint<'a> = &'a mut dyn FnMut(u64, &EmbeddingStore);

/// The GraphVite system handle.
pub struct Trainer {
    graph: Arc<Graph>,
    config: TrainConfig,
}

impl Trainer {
    pub fn new(graph: Graph, config: TrainConfig) -> Result<Self> {
        config.validate()?;
        anyhow::ensure!(
            graph.num_nodes() >= config.partitions(),
            "graph smaller than partition count"
        );
        Ok(Trainer { graph: Arc::new(graph), config })
    }

    pub fn graph(&self) -> &Graph {
        &self.graph
    }

    pub fn config(&self) -> &TrainConfig {
        &self.config
    }

    /// Train to completion.
    pub fn train(&mut self) -> Result<TrainResult> {
        self.train_with_callback(None)
    }

    /// Train, invoking `checkpoint` after every pool pass (used by the
    /// Figure-4 performance-curve experiments). Note: with `fix_context`
    /// the store's *context* matrix is only synchronized at the end of
    /// training; checkpoints see current vertex embeddings (the ones all
    /// evaluations use) and stale context rows.
    pub fn train_with_callback(&mut self, mut checkpoint: Option<Checkpoint>) -> Result<TrainResult> {
        let cfg = self.config.clone();
        let graph = Arc::clone(&self.graph);
        let counters = Arc::new(Counters::default());

        // ---- preprocessing (paper's "preprocessing time" column) ----
        let mut prep = Stopwatch::started();
        let num_parts = cfg.partitions();
        let parts = Arc::new(Partitioner::degree_zigzag(&graph, num_parts));
        let neg = Arc::new(NegativeSampler::new(&graph, &parts));
        let sched = EpisodeSchedule::new(num_parts, cfg.num_workers, cfg.fix_context);
        let artifact: Option<ArtifactMeta> = match cfg.backend {
            BackendKind::Pjrt => {
                let manifest = crate::runtime::default_manifest()?;
                Some(
                    manifest
                        .find_train(parts.max_part_size(), cfg.dim)
                        .context("selecting train artifact")?
                        .clone(),
                )
            }
            // the pure-rust backends (scalar + unrolled-simd) train
            // directly on the gathered partitions — no AOT artifact
            BackendKind::Native | BackendKind::Simd => None,
        };
        let mut store = EmbeddingStore::init(graph.num_nodes(), cfg.dim, cfg.seed);
        prep.stop();

        // ---- training ----
        let mut train_sw = Stopwatch::started();
        let total_samples = cfg.total_samples(self.graph.num_edges()).max(1);
        let pool_size = cfg.episode_size.saturating_mul(num_parts).max(cfg.batch_size);
        let num_pools = (total_samples as usize).div_ceil(pool_size);

        let base_rng = Rng::new(cfg.seed);
        let mut loss_curve: Vec<f32> = Vec::new();
        let mut samples_done: u64 = 0;

        // Shared read-only sampling structures, built ONCE. (Building the
        // walker / departure table / edge sampler per pool fill used to
        // rebuild |V| alias tables per sampler thread per pool on weighted
        // graphs and dominated the profile — EXPERIMENTS.md §Perf.)
        let sampling = SamplingShared::build(&graph, &cfg);

        std::thread::scope(|scope| -> Result<()> {
            // ---- device worker threads ----
            let (handles, job_txs, result_rx) = spawn_workers(
                scope,
                &cfg,
                artifact.as_ref(),
                Arc::clone(&neg),
                Arc::clone(&counters),
                &base_rng,
            );

            // ---- pool production ----
            let sampling_ref = &sampling;
            let counters_ref = &counters;
            let fill_pool = |pool: &mut SamplePool, pool_idx: usize, target: usize| {
                let t0 = std::time::Instant::now();
                fill_pool_parallel(sampling_ref, &cfg, &base_rng, pool_idx, target, pool);
                counters_ref.add(&counters_ref.sampling_nanos, t0.elapsed().as_nanos() as u64);
            };

            let pair = Arc::new(PoolPair::new());
            let producer_handle = if cfg.collaboration {
                let pair = Arc::clone(&pair);
                let cfg2 = cfg.clone();
                let base2 = base_rng.clone();
                let counters2 = Arc::clone(&counters);
                Some(scope.spawn(move || {
                    let mut buf = SamplePool::new();
                    for pool_idx in 0..num_pools {
                        buf.clear();
                        let t0 = std::time::Instant::now();
                        fill_pool_parallel(sampling_ref, &cfg2, &base2, pool_idx, pool_size, &mut buf);
                        counters2.add(&counters2.sampling_nanos, t0.elapsed().as_nanos() as u64);
                        buf = pair.publish(buf);
                    }
                    pair.finish();
                }))
            } else {
                None
            };

            // ---- consumption: episodes over each pool ----
            let consume_pool = |store: &mut EmbeddingStore,
                                pool: SamplePool,
                                samples_done: &mut u64,
                                loss_curve: &mut Vec<f32>|
             -> Result<()> {
                counters.add(&counters.samples_generated, pool.len() as u64);
                let mut grid = BlockGrid::redistribute(&pool, &parts);
                for g in 0..sched.num_groups() {
                    let mut ep_loss = 0.0f64;
                    let mut ep_trained = 0u64;
                    for w in 0..sched.waves_per_group() {
                        let wave = sched.wave(g, w);
                        let lr = cfg.lr
                            * (1.0 - *samples_done as f32 / total_samples as f32).max(1e-4);
                        let mut outstanding = 0usize;
                        for a in &wave {
                            let block = grid.take_block(a.vid, a.cid);
                            let vcap = crate::gpu::planned_capacity(
                                &cfg,
                                artifact.as_ref(),
                                parts.part_size(a.vid),
                            );
                            let ccap = crate::gpu::planned_capacity(
                                &cfg,
                                artifact.as_ref(),
                                parts.part_size(a.cid),
                            );
                            let mut vertex = Vec::new();
                            store.gather_partition(&parts, a.vid, vcap, Matrix::Vertex, &mut vertex);
                            counters.add(&counters.bytes_to_device, (vertex.len() * 4) as u64);
                            let context = if cfg.fix_context && g + w > 0 {
                                None // resident on the worker since the first episode
                            } else {
                                let mut c = Vec::new();
                                store.gather_partition(&parts, a.cid, ccap, Matrix::Context, &mut c);
                                counters.add(&counters.bytes_to_device, (c.len() * 4) as u64);
                                Some(c)
                            };
                            let is_last_group =
                                g == sched.num_groups() - 1 && w == sched.waves_per_group() - 1;
                            job_txs[a.worker]
                                .send(JobMsg::Train(Job {
                                    vid: a.vid,
                                    cid: a.cid,
                                    block,
                                    vertex,
                                    context,
                                    return_context: !cfg.fix_context || is_last_group,
                                    lr,
                                }))
                                .map_err(|_| anyhow::anyhow!("worker channel closed"))?;
                            outstanding += 1;
                        }
                        for _ in 0..outstanding {
                            let res: JobResult = result_rx
                                .recv()
                                .map_err(|_| anyhow::anyhow!("workers hung up"))??;
                            store.scatter_partition(&parts, res.vid, Matrix::Vertex, &res.vertex);
                            counters.add(&counters.bytes_from_device, (res.vertex.len() * 4) as u64);
                            if let Some(ctx) = &res.context {
                                store.scatter_partition(&parts, res.cid, Matrix::Context, ctx);
                                counters.add(&counters.bytes_from_device, (ctx.len() * 4) as u64);
                            }
                            ep_loss += res.loss as f64 * res.trained as f64;
                            ep_trained += res.trained;
                            *samples_done += res.trained;
                        }
                    }
                    counters.add(&counters.episodes, 1);
                    if ep_trained > 0 {
                        loss_curve.push((ep_loss / ep_trained as f64) as f32);
                    }
                    if cfg.log_every > 0 && loss_curve.len() % cfg.log_every == 0 {
                        eprintln!(
                            "episode {} loss {:.4} ({}/{} samples)",
                            loss_curve.len(),
                            loss_curve.last().unwrap(),
                            samples_done,
                            total_samples
                        );
                    }
                }
                Ok(())
            };

            if cfg.collaboration {
                while let Some(pool) = pair.take() {
                    consume_pool(&mut store, pool, &mut samples_done, &mut loss_curve)?;
                    pair.recycle(SamplePool::new());
                    if let Some(cb) = checkpoint.as_mut() {
                        cb(samples_done, &store);
                    }
                }
            } else {
                let mut buf = SamplePool::new();
                for pool_idx in 0..num_pools {
                    buf.clear();
                    fill_pool(&mut buf, pool_idx, pool_size);
                    let pool = std::mem::take(&mut buf);
                    consume_pool(&mut store, pool, &mut samples_done, &mut loss_curve)?;
                    if let Some(cb) = checkpoint.as_mut() {
                        cb(samples_done, &store);
                    }
                }
            }

            // drain cached contexts (fix_context) + stop workers
            for tx in &job_txs {
                let _ = tx.send(JobMsg::Stop);
            }
            if let Some(h) = producer_handle {
                h.join().map_err(|_| anyhow::anyhow!("producer panicked"))?;
            }
            for h in handles {
                h.join().map_err(|_| anyhow::anyhow!("worker panicked"))??;
            }
            Ok(())
        })?;

        train_sw.stop();
        let snapshot = counters.snapshot();
        let stats = TrainStats {
            train_secs: train_sw.secs(),
            preprocess_secs: prep.secs(),
            final_loss: loss_curve.last().copied().unwrap_or(f32::NAN),
            loss_curve,
            counters: snapshot,
        };
        Ok(TrainResult { embeddings: store, stats })
    }
}

/// Read-only sampling structures shared by every sampler thread and every
/// pool fill (built once per training run).
struct SamplingShared<'g> {
    walker: Option<RandomWalker<'g>>,
    departure: Option<AliasTableShared>,
    edge_sampler: Option<EdgeSampler>,
}

type AliasTableShared = crate::sampling::AliasTable;

impl<'g> SamplingShared<'g> {
    fn build(graph: &'g Graph, cfg: &TrainConfig) -> Self {
        if cfg.online_augmentation {
            SamplingShared {
                walker: Some(RandomWalker::new(graph)),
                departure: Some(OnlineAugmenter::departure_table(graph)),
                edge_sampler: None,
            }
        } else {
            SamplingShared {
                walker: None,
                departure: None,
                edge_sampler: Some(EdgeSampler::new(graph)),
            }
        }
    }
}

/// Fill one pool with `target` samples using `num_samplers` CPU threads
/// (parallel online augmentation, Algorithm 2), then shuffle (Table 7).
fn fill_pool_parallel(
    shared: &SamplingShared<'_>,
    cfg: &TrainConfig,
    base_rng: &Rng,
    pool_idx: usize,
    target: usize,
    out: &mut SamplePool,
) {
    let num_samplers = cfg.num_samplers;
    let per_thread = target.div_ceil(num_samplers);
    let aug_cfg = AugmentConfig {
        walk_length: cfg.walk_length,
        augmentation_distance: cfg.augmentation_distance,
    };

    let mut parts: Vec<SamplePool> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..num_samplers)
            .map(|i| {
                let rng = base_rng.split((pool_idx as u64) << 20 | i as u64 | 1 << 40);
                scope.spawn(move || {
                    let mut local = SamplePool::with_capacity(per_thread);
                    match (&shared.walker, &shared.departure, &shared.edge_sampler) {
                        (Some(walker), Some(dep), _) => {
                            let mut aug = OnlineAugmenter::new(walker, dep, aug_cfg, rng);
                            aug.fill(&mut local, per_thread);
                        }
                        (_, _, Some(es)) => {
                            let mut rng = rng;
                            es.fill(&mut local, per_thread, &mut rng);
                        }
                        _ => unreachable!(),
                    }
                    local
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    out.clear();
    out.reserve(target);
    for p in &mut parts {
        out.append(p);
    }
    out.truncate(target);
    let mut rng = base_rng.split(0xF00D ^ pool_idx as u64);
    shuffle::shuffle(cfg.shuffle, out, cfg.augmentation_distance.max(2), &mut rng);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::pool::ShuffleKind;

    fn small_cfg() -> TrainConfig {
        TrainConfig {
            dim: 8,
            epochs: 3,
            num_workers: 2,
            num_samplers: 2,
            episode_size: 2_000,
            batch_size: 64,
            backend: BackendKind::Native,
            shuffle: ShuffleKind::Pseudo,
            ..TrainConfig::default()
        }
    }

    #[test]
    fn trains_karate_native() {
        let g = generators::karate_club();
        let mut t = Trainer::new(g, TrainConfig { num_workers: 2, ..small_cfg() }).unwrap();
        let r = t.train().unwrap();
        assert_eq!(r.embeddings.num_nodes(), 34);
        assert!(r.stats.counters.samples_trained > 0);
        assert!(r.stats.final_loss.is_finite());
    }

    #[test]
    fn loss_decreases_on_structured_graph() {
        let g = generators::planted_partition(500, 5, 20.0, 0.05, 7);
        let cfg = TrainConfig { epochs: 20, ..small_cfg() };
        let mut t = Trainer::new(g, cfg).unwrap();
        let r = t.train().unwrap();
        let curve = &r.stats.loss_curve;
        assert!(curve.len() >= 4, "curve {curve:?}");
        let head: f32 = curve[..2].iter().sum::<f32>() / 2.0;
        let tail: f32 = curve[curve.len() - 2..].iter().sum::<f32>() / 2.0;
        assert!(tail < head, "head {head} tail {tail}");
    }

    #[test]
    fn sequential_mode_matches_sample_budget() {
        let g = generators::barabasi_albert(300, 3, 3);
        let edges = g.num_edges() as u64;
        let cfg = TrainConfig { collaboration: false, epochs: 2, ..small_cfg() };
        let mut t = Trainer::new(g, cfg).unwrap();
        let r = t.train().unwrap();
        // trained at least the requested budget (pool granularity rounds up)
        assert!(r.stats.counters.samples_trained >= 2 * edges);
    }

    #[test]
    fn ablations_run() {
        let g = generators::barabasi_albert(200, 3, 4);
        for (aug, collab, fixc) in [
            (false, true, true),
            (true, false, false),
            (false, false, false),
        ] {
            let cfg = TrainConfig {
                online_augmentation: aug,
                collaboration: collab,
                fix_context: fixc,
                epochs: 1,
                ..small_cfg()
            };
            let mut t = Trainer::new(g.clone(), cfg).unwrap();
            let r = t.train().unwrap();
            assert!(r.stats.counters.samples_trained > 0);
        }
    }

    #[test]
    fn more_partitions_than_workers() {
        // paper section 3.2: "any number of partitions greater than n",
        // processed in subgroups of n orthogonal blocks per episode.
        let g = generators::planted_partition(400, 4, 16.0, 0.05, 23);
        let cfg = TrainConfig {
            num_workers: 2,
            num_partitions: 6,
            fix_context: false,
            epochs: 120,
            ..small_cfg()
        };
        let mut t = Trainer::new(g.clone(), cfg).unwrap();
        let r = t.train().unwrap();
        assert!(r.stats.counters.samples_trained > 0);
        assert!(r.stats.final_loss.is_finite());
        // quality must not collapse vs the square grid
        let rep = crate::experiments::classify(&r.embeddings, &g, 0.05, 7);
        assert!(rep.micro_f1 > 0.4, "micro {}", rep.micro_f1);
    }

    #[test]
    fn partitions_must_be_multiple_of_workers() {
        let g = generators::karate_club();
        let cfg = TrainConfig {
            num_workers: 2,
            num_partitions: 5,
            fix_context: false,
            ..small_cfg()
        };
        assert!(Trainer::new(g, cfg).is_err());
    }

    #[test]
    fn fix_context_rejects_extra_partitions() {
        let g = generators::karate_club();
        let cfg = TrainConfig {
            num_workers: 2,
            num_partitions: 4,
            fix_context: true,
            ..small_cfg()
        };
        assert!(Trainer::new(g, cfg).is_err());
    }

    #[test]
    fn checkpoints_fire() {
        let g = generators::barabasi_albert(200, 3, 5);
        let mut cfg = small_cfg();
        cfg.episode_size = 500; // several pools
        cfg.epochs = 4;
        let mut t = Trainer::new(g, cfg).unwrap();
        let mut calls = 0;
        let mut cb = |done: u64, store: &EmbeddingStore| {
            assert!(done > 0);
            assert_eq!(store.dim(), 8);
            calls += 1;
        };
        t.train_with_callback(Some(&mut cb)).unwrap();
        assert!(calls >= 2, "calls {calls}");
    }
}
