//! xoshiro256** PRNG + splitmix64 seeding.
//!
//! The offline crate set has no `rand`, so this is the project's RNG
//! substrate: fast (sub-ns per u64), splittable per worker thread (jump
//! via reseeding through splitmix64), with the distribution helpers the
//! samplers need (uniform ranges, f32/f64 unit, shuffling).

/// Stream-id namespaces for [`Rng::split`] / [`Rng::stream`].
///
/// Every subsystem that derives per-thread RNGs from the run's base seed
/// must draw its stream ids from a *disjoint* region of the u64 stream
/// domain, or two subsystems can silently end up on the same stream (the
/// seed bug this replaces: worker streams `0xBEEF ^ i`, shuffle streams
/// `0xF00D ^ pool_idx` and sampler streams `pool_idx << 20 | i` all lived
/// in one flat domain and collided for large `pool_idx`). The top byte of
/// the id is the namespace tag; the low 56 bits are the subsystem-local
/// index, whose layout each constant documents. New subsystems take the
/// next tag here — never an ad-hoc constant at the call site.
pub mod streams {
    /// Device-worker training streams (negative sampling). Low bits:
    /// worker index.
    pub const WORKER: u64 = 0x01 << 56;
    /// Sampler-thread streams (online augmentation / edge sampling).
    /// Low bits: `pool_idx << 16 | sampler_idx` (sampler count < 2^16,
    /// pool index < 2^40).
    pub const SAMPLER: u64 = 0x02 << 56;
    /// Pool-shuffle streams. Low bits: pool index.
    pub const SHUFFLE: u64 = 0x03 << 56;
}

/// splitmix64 step — used to expand a single u64 seed into a full state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** — our workhorse PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed deterministically from a single u64 (via splitmix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        // xoshiro must not be seeded with all zeros.
        let all_zero = s.iter().all(|&x| x == 0);
        Rng {
            s: if all_zero { [1, 2, 3, 4] } else { s },
        }
    }

    /// Snapshot the raw xoshiro state (for checkpointing). Restore with
    /// [`Self::from_state`]; the pair round-trips bitwise, so a resumed
    /// stream continues exactly where the snapshot was taken.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild an RNG from a state snapshot taken by [`Self::state`].
    /// An all-zero state is invalid for xoshiro and is rejected here so a
    /// corrupt checkpoint cannot construct a degenerate generator.
    pub fn from_state(s: [u64; 4]) -> Result<Self, &'static str> {
        if s.iter().all(|&x| x == 0) {
            return Err("all-zero xoshiro256** state");
        }
        Ok(Rng { s })
    }

    /// Derive an independent stream for worker `i` (used to give each
    /// sampler / trainer thread its own deterministic RNG). Callers that
    /// share one base RNG across subsystems should go through
    /// [`Self::stream`] so their id domains cannot collide.
    pub fn split(&self, i: u64) -> Self {
        let mut sm = self.s[0] ^ self.s[3] ^ (i.wrapping_mul(0xA0761D6478BD642F));
        Rng::new(splitmix64(&mut sm))
    }

    /// [`Self::split`] with a namespaced stream id: `namespace` is one of
    /// the [`streams`] constants (top byte), `id` the subsystem-local
    /// index (must fit the low 56 bits).
    pub fn stream(&self, namespace: u64, id: u64) -> Self {
        debug_assert!(id < (1 << 56), "stream id {id:#x} spills into the namespace byte");
        self.split(namespace | id)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform integer in [0, n). n must be > 0.
    /// Lemire's nearly-divisionless bounded sampling.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [0, n).
    #[inline]
    pub fn below_usize(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<u32> {
        let mut p: Vec<u32> = (0..n as u32).collect();
        self.shuffle(&mut p);
        p
    }

    /// Standard normal via Box–Muller (used for embedding init variants).
    pub fn normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..64 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn splits_are_independent_streams() {
        let base = Rng::new(1);
        let mut a = base.split(0);
        let mut b = base.split(1);
        let xs: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..16).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = rng.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval_and_mean() {
        let mut rng = Rng::new(11);
        let mut sum = 0.0;
        const N: usize = 100_000;
        for _ in 0..N {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / N as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(13);
        const N: usize = 50_000;
        let xs: Vec<f64> = (0..N).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / N as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / N as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn stream_namespaces_are_disjoint() {
        // The ids the coordinator actually constructs (worker, sampler,
        // shuffle) must be pairwise distinct u64s over realistic index
        // ranges — the collision the flat pre-namespace domain allowed.
        let mut seen = std::collections::HashSet::new();
        for w in 0..64u64 {
            assert!(seen.insert(streams::WORKER | w));
        }
        for pool in 0..512u64 {
            for s in 0..16u64 {
                assert!(seen.insert(streams::SAMPLER | (pool << 16) | s));
            }
            assert!(seen.insert(streams::SHUFFLE | pool));
        }
    }

    #[test]
    fn stream_derives_from_namespace_and_id() {
        let base = Rng::new(9);
        let mut a = base.stream(streams::WORKER, 3);
        let mut b = base.split(streams::WORKER | 3);
        assert_eq!(a.next_u64(), b.next_u64());
        let mut c = base.stream(streams::SHUFFLE, 3);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn zero_seed_is_valid() {
        let mut rng = Rng::new(0);
        assert_ne!(rng.next_u64(), rng.next_u64());
    }
}
