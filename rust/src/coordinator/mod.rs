//! The GraphVite coordinator: ties parallel online augmentation (CPU
//! sampler threads), the double-buffered sample-pool pair, the episode
//! scheduler, the pipelined transfer engine and the device workers into
//! the paper's full hybrid system (Figure 1 / Algorithm 3).
//!
//! Thread topology during [`Trainer::train`]:
//!
//! ```text
//!   producer thread ──  fills pool (num_samplers sampler threads)
//!        │ PoolPair (double buffer, §3.3 collaboration strategy;
//!        │           drained pools recycle back — zero realloc)
//!        ▼
//!   main thread      ── refill pool into n×n BlockGrid (sharded across
//!                       num_samplers scoped threads, block buffers
//!                       recycled), then per episode group: plan
//!                       transfers (residency), gather partitions into
//!                       recycled buffers, dispatch ALL waves of the
//!                       group, scatter results as they arrive; while
//!                       the LAST group's results drain, a helper thread
//!                       takes the next pool and redistributes it into a
//!                       second BlockGrid (overlapped refill — the
//!                       between-pools refill never serializes on the
//!                       main thread in collaboration mode)
//!        │ mpsc per worker            ▲ results channel
//!        ▼                            │
//!   worker threads   ── one per simulated GPU; owns a gpu::Backend
//!                       (PJRT client+executable or native trainer) and a
//!                       residency cache of pinned partitions, draws
//!                       restricted negatives, trains its block
//! ```
//!
//! **Prefetch fence rule.** Waves inside one episode group are slices of
//! a latin-square diagonal: mutually row- *and* column-disjoint. So the
//! coordinator may gather and dispatch wave `w+1` while wave `w` is still
//! training — nothing wave `w` will scatter overlaps what wave `w+1`
//! gathers — and only **group boundaries** are fences (the next group
//! reuses every partition, so all scatters must land first). This is the
//! `pipeline_transfers` flag; with it off, each wave is drained before
//! the next is dispatched (the PR-2 serial dispatch). Both orders produce
//! bitwise-identical embeddings: scatters of orthogonal blocks commute,
//! per-worker job order is unchanged, and the learning-rate schedule is
//! driven by *dispatched* samples (known at send time) rather than
//! received results — see `rust/tests/pipeline_equivalence.rs`.
//!
//! Partition movement itself (gathers, scatters, residency planning,
//! buffer recycling) lives in [`transfer::TransferEngine`]; the §3.4
//! `fix_context` context cache is the special case the engine's
//! generalized residency subsumes.
//!
//! The coordinator is backend-agnostic: workers construct whatever
//! [`crate::gpu::Backend`] the config selects (`native`, `simd`, or
//! `pjrt`) on their own threads, and the only backend-specific fact the
//! coordinator consumes is the partition padding rule
//! ([`crate::gpu::planned_capacity`]).
//!
//! Episode semantics (what the `episodes` counter and `log_every` lines
//! count): one *episode* = one orthogonal group — for `P` partitions, the
//! `P` blocks of a latin-square diagonal from
//! [`crate::scheduler::EpisodeSchedule`], run as `P / C` waves of `C`
//! concurrently-training blocks (`C` = total worker capacity; worker `i`
//! holds `capacities[i]` of each wave's blocks — one each for the
//! homogeneous default) with no shared rows, hence no synchronization —
//! totalling `episode_size` positive samples; one *pool pass* = `P`
//! episodes covering all P² blocks, after which the double-buffered pool
//! pair swaps. The learning rate decays linearly over total samples,
//! matching the paper's SGD schedule.
//!
//! Ablation flags in [`TrainConfig`](crate::config::TrainConfig) switch
//! off each paper component: `online_augmentation` (plain edge sampling
//! instead), `collaboration` (fill and train sequentially), `fix_context`
//! (transfer context partitions every episode), `pipeline_transfers` and
//! `residency` (the PR-3 transfer engine) — the first three drive
//! Table 6, the last two `bench_pipeline`.

pub mod checkpoint;
pub mod transfer;
pub mod transport;
mod worker;

pub use checkpoint::{load_checkpoint, save_checkpoint, CheckpointState, TrainCheckpoint};
pub use transport::{Transport, TransportReport};

use std::net::TcpListener;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::config::{BackendKind, TrainConfig, WorkerMode};
use crate::embedding::{EmbeddingStore, Matrix};
use crate::graph::{Graph, GraphStore};
use crate::metrics::{Counters, TrainStats};
use crate::partition::{Partitioner, Partitioning};
use crate::pool::shuffle;
use crate::pool::{BlockGrid, PoolPair, SamplePool};
use crate::runtime::ArtifactMeta;
use crate::sampling::{AugmentConfig, EdgeSampler, NegativeSampler, OnlineAugmenter, RandomWalker};
use crate::scheduler::{Assignment, EpisodeSchedule};
use crate::util::rng::{streams, Rng};
use crate::util::timer::Stopwatch;

use transfer::{JournalEntry, JournalShipment, ShipPlan, TransferEngine};
use transport::{make_assignments, LocalTransport, SocketTransport};
use worker::{spawn_workers, Job, JobMsg, JobResult, Reply, Shipment, SyncReply, Takeover};

/// Decorator applied to the transport before training starts (the fault
/// -injection seam: tests wrap the real transport in a
/// [`transport::FlakyTransport`] without touching the episode loop).
/// Consumed by the next [`Trainer::train`] call.
pub type TransportWrapper = Box<dyn FnMut(Box<dyn Transport>) -> Box<dyn Transport> + Send>;

/// Output of a training run.
#[derive(Debug)]
pub struct TrainResult {
    pub embeddings: EmbeddingStore,
    pub stats: TrainStats,
}

/// Checkpoint callback: (positive samples trained so far, current store).
pub type Checkpoint<'a> = &'a mut dyn FnMut(u64, &EmbeddingStore);

/// What a [`StateObserver`] tells the trainer after a checkpoint: keep
/// going, or stop cleanly at this pool boundary (the state it just saw is
/// a complete resume point).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrainFlow {
    Continue,
    Stop,
}

/// Full-state checkpoint observer, invoked after every pool pass with the
/// complete resumable state (synced store, worker RNG snapshots, LR
/// schedule position). Used by `--checkpoint` to persist `.gvck` files
/// and by `--stop-after-pools` / the bitwise-resume test to end a run
/// early at a pool boundary.
pub type StateObserver<'a> = &'a mut dyn FnMut(&CheckpointState<'_>) -> Result<TrainFlow>;

/// Internal: the three observer shapes [`Trainer::train_impl`] accepts.
enum Observer<'a, 'b> {
    None,
    Legacy(Checkpoint<'a>),
    State(StateObserver<'b>),
}

/// The GraphVite system handle.
pub struct Trainer {
    graph: Arc<dyn GraphStore>,
    config: TrainConfig,
    /// Pre-bound listener for `workers = "tcp://..."` runs (tests bind
    /// port 0 and read the ephemeral address back; when unset the trainer
    /// binds the configured address itself).
    worker_listener: Option<TcpListener>,
    /// Fault-injection seam, consumed by the next train call.
    transport_wrapper: Option<TransportWrapper>,
    /// Wire ledger of the last socket-transport run (`None` after local
    /// runs — the in-process channels have no wire to account for).
    last_transport: Option<TransportReport>,
    /// Checkpoint-on-fault destination: when set, a run that dies after
    /// worker-failure recovery is exhausted first writes a `.gvck` of
    /// the last completed pool boundary here.
    fault_checkpoint: Option<std::path::PathBuf>,
}

impl Trainer {
    /// Train off an in-RAM graph (the edge-list loader / generators).
    pub fn new(graph: Graph, config: TrainConfig) -> Result<Self> {
        Self::from_store(Arc::new(graph), config)
    }

    /// Train off any [`GraphStore`] — in particular the out-of-core
    /// [`PagedCsr`](crate::graph::PagedCsr), which streams successor
    /// pages from disk through its bounded cache while training runs.
    /// Same seed + config produce bitwise-identical embeddings whichever
    /// store backs the graph (see `rust/tests/ondisk.rs`).
    pub fn from_store(graph: Arc<dyn GraphStore>, config: TrainConfig) -> Result<Self> {
        config.validate()?;
        anyhow::ensure!(
            graph.num_nodes() >= config.partitions(),
            "graph smaller than partition count"
        );
        Ok(Trainer {
            graph,
            config,
            worker_listener: None,
            transport_wrapper: None,
            last_transport: None,
            fault_checkpoint: None,
        })
    }

    pub fn graph(&self) -> &dyn GraphStore {
        &*self.graph
    }

    pub fn config(&self) -> &TrainConfig {
        &self.config
    }

    /// Use an already-bound listener for the next `workers = "tcp://..."`
    /// run instead of binding the configured address (tests bind port 0).
    pub fn set_worker_listener(&mut self, listener: TcpListener) {
        self.worker_listener = Some(listener);
    }

    /// Install a transport decorator for the next train call (the
    /// fault-injection seam — see [`transport::FlakyTransport`]).
    pub fn set_transport_wrapper(&mut self, wrapper: TransportWrapper) {
        self.transport_wrapper = Some(wrapper);
    }

    /// The verified wire ledger of the last completed socket-transport
    /// run (`None` for local runs).
    pub fn transport_report(&self) -> Option<TransportReport> {
        self.last_transport
    }

    /// Cut a `.gvck` at `path` if training dies after worker-failure
    /// recovery is exhausted: the checkpoint captures the last completed
    /// pool boundary, so a crashed run loses at most one pool —
    /// [`load_checkpoint`] + [`Trainer::train_resumable`] continue it
    /// bitwise-identically. Costs one in-memory copy of the store while
    /// training runs.
    pub fn set_fault_checkpoint(&mut self, path: impl Into<std::path::PathBuf>) {
        self.fault_checkpoint = Some(path.into());
    }

    /// Train to completion.
    pub fn train(&mut self) -> Result<TrainResult> {
        self.train_impl(None, Observer::None)
    }

    /// Train, invoking `checkpoint` after every pool pass (used by the
    /// Figure-4 performance-curve experiments). Worker-resident
    /// partitions (`fix_context` / `residency`) are synchronized back
    /// into the store before every checkpoint, so callbacks always see
    /// current vertex *and* context rows.
    pub fn train_with_callback(&mut self, checkpoint: Option<Checkpoint>) -> Result<TrainResult> {
        match checkpoint {
            Some(cb) => self.train_impl(None, Observer::Legacy(cb)),
            None => self.train_impl(None, Observer::None),
        }
    }

    /// Resumable training: continue from a loaded [`TrainCheckpoint`]
    /// (or start fresh with `None`), invoking `observer` with the full
    /// resumable state after every pool pass. The observer may persist
    /// the state ([`save_checkpoint`]) and/or end the run early at the
    /// pool boundary by returning [`TrainFlow::Stop`].
    ///
    /// Resume is **bitwise-equivalent**: an interrupted-and-resumed run
    /// produces exactly the bytes of the uninterrupted run with the same
    /// config (pinned in `rust/tests/checkpoint.rs`). The config must
    /// therefore describe the *full* target run — same seed, geometry
    /// and `--epochs` as the run that wrote the checkpoint.
    pub fn train_resumable(
        &mut self,
        resume: Option<TrainCheckpoint>,
        observer: Option<StateObserver>,
    ) -> Result<TrainResult> {
        match observer {
            Some(obs) => self.train_impl(resume, Observer::State(obs)),
            None => self.train_impl(resume, Observer::None),
        }
    }

    fn train_impl(
        &mut self,
        resume: Option<TrainCheckpoint>,
        mut observer: Observer,
    ) -> Result<TrainResult> {
        let cfg = self.config.clone();
        let graph = Arc::clone(&self.graph);
        let counters = Arc::new(Counters::default());

        // ---- preprocessing (paper's "preprocessing time" column) ----
        let mut prep = Stopwatch::started();
        let num_parts = cfg.partitions();
        let parts = Arc::new(Partitioner::degree_zigzag(&*graph, num_parts));
        // Weights are kept around for tcp runs: the handshake ships them
        // bit-exactly so remote workers (no graph) build identical alias
        // tables. from_weights(partition_weights(..)) is exactly what
        // NegativeSampler::new does, so local runs are unchanged.
        let neg_weights = NegativeSampler::partition_weights(&*graph, &parts);
        let neg = Arc::new(NegativeSampler::from_weights(&neg_weights));
        let sched = {
            // capacity-aware waves: worker i takes capacities[i] blocks
            // per wave (the homogeneous default is one each — the PR-3
            // schedule, bitwise)
            let s =
                EpisodeSchedule::with_capacities(num_parts, &cfg.capacities(), cfg.fix_context);
            // group order is part of the training trajectory: only the
            // residency mode pays for the sticky ordering
            if cfg.residency { s.with_residency_order() } else { s }
        };
        let artifact: Option<ArtifactMeta> = match cfg.backend {
            BackendKind::Pjrt => {
                let manifest = crate::runtime::default_manifest()?;
                Some(
                    manifest
                        .find_train(parts.max_part_size(), cfg.dim)
                        .context("selecting train artifact")?
                        .clone(),
                )
            }
            // the pure-rust backends (scalar + unrolled-simd) train
            // directly on the gathered partitions — no AOT artifact
            BackendKind::Native | BackendKind::Simd => None,
        };
        let num_edges = self.graph.num_edges();
        let total_samples = cfg.total_samples(num_edges).max(1);
        let pool_size = cfg.episode_size.saturating_mul(num_parts).max(cfg.batch_size);
        let num_pools = (total_samples as usize).div_ceil(pool_size);
        // Resume picks up the pool cursor, the synced store, the LR
        // schedule position and the worker RNG streams; everything else
        // (pools, grids, transfer-engine residency) rebuilds
        // deterministically from `seed` + pool index — see checkpoint.rs.
        let (mut store, start_pool, resume_rngs, resume_done, resume_planned) = match resume {
            Some(ck) => {
                validate_resume(
                    &ck, &cfg, &*graph, num_parts, total_samples, pool_size, num_pools,
                )?;
                let pools = ck.pools_done as usize;
                (ck.store, pools, Some(ck.worker_rngs), ck.samples_done, ck.samples_planned)
            }
            None => (EmbeddingStore::init(graph.num_nodes(), cfg.dim, cfg.seed), 0, None, 0, 0),
        };
        prep.stop();

        // ---- training ----
        let mut train_sw = Stopwatch::started();
        let base_rng = Rng::new(cfg.seed);
        let mut loss_curve: Vec<f32> = Vec::new();
        let mut samples_done: u64 = resume_done;
        let mut pools_done: u64 = start_pool as u64;

        // Shared read-only sampling structures, built ONCE. (Building the
        // walker / departure table / edge sampler per pool fill used to
        // rebuild |V| alias tables per sampler thread per pool on weighted
        // graphs and dominated the profile — EXPERIMENTS.md §Perf.)
        let sampling = SamplingShared::build(&*graph, &cfg);

        let mut pre_listener = self.worker_listener.take();
        let mut wrapper = self.transport_wrapper.take();
        self.last_transport = None;

        // Each worker slot's RNG stream state at run start — the recovery
        // journal's per-slot replay base until the first group fence
        // refreshes it (identical derivation to spawn_workers /
        // make_assignments, so the journal's idea of a slot's stream is
        // bitwise the worker's).
        let init_worker_rngs: Vec<[u64; 4]> = (0..cfg.num_workers)
            .map(|i| match resume_rngs.as_deref() {
                Some(states) => states[i],
                None => base_rng.stream(streams::WORKER, i as u64).state(),
            })
            .collect();

        // Checkpoint-on-fault stash: seeded with the run's starting state
        // (a failure in the very first pool resumes from the start),
        // refreshed at every completed pool boundary, written out only on
        // the error path after recovery is exhausted.
        let fault_path = self.fault_checkpoint.clone();
        let mut fault_stash: Option<TrainCheckpoint> = fault_path.as_ref().map(|_| {
            TrainCheckpoint {
                seed: cfg.seed,
                num_edges: num_edges as u64,
                partitions: num_parts as u64,
                total_samples,
                pool_size: pool_size as u64,
                pools_done: start_pool as u64,
                samples_planned: resume_planned,
                samples_done: resume_done,
                worker_rngs: init_worker_rngs.clone(),
                store: store.clone(),
            }
        });

        let scope_res = std::thread::scope(|scope| -> Result<Option<TransportReport>> {
            // ---- device workers, behind the transport seam ----
            // Local mode spawns the in-process worker threads of PRs 1-6
            // (bitwise-pinned); tcp mode accepts `num_workers` remote
            // `graphvite worker` processes instead — same protocol, same
            // planner, zero worker threads here.
            let (handles, transport) = match &cfg.worker_mode {
                WorkerMode::Local => {
                    let (handles, job_txs, result_rx) = spawn_workers(
                        scope,
                        &cfg,
                        artifact.as_ref(),
                        Arc::clone(&neg),
                        Arc::clone(&counters),
                        &base_rng,
                        resume_rngs.as_deref(),
                    )?;
                    let local = LocalTransport::new(job_txs, result_rx);
                    (handles, Box::new(local) as Box<dyn Transport>)
                }
                WorkerMode::Tcp(addr) => {
                    let listener = match pre_listener.take() {
                        Some(l) => l,
                        None => TcpListener::bind(addr.as_str())
                            .with_context(|| format!("binding worker listener on {addr}"))?,
                    };
                    let assignments = make_assignments(
                        &cfg,
                        num_parts,
                        &neg_weights,
                        &base_rng,
                        resume_rngs.as_deref(),
                    )?;
                    let recv_timeout = (cfg.worker_timeout_secs > 0)
                        .then(|| Duration::from_secs(cfg.worker_timeout_secs));
                    let heartbeat = (cfg.heartbeat_secs > 0)
                        .then(|| Duration::from_secs(cfg.heartbeat_secs));
                    // recovery keeps the listener open for rejoins
                    let socket = SocketTransport::accept(
                        listener,
                        assignments,
                        recv_timeout,
                        heartbeat,
                        cfg.recovery_enabled(),
                    )?;
                    (Vec::new(), Box::new(socket) as Box<dyn Transport>)
                }
            };
            let transport = match wrapper.take() {
                Some(mut wrap) => wrap(transport),
                None => transport,
            };

            // ---- pool production ----
            let sampling_ref = &sampling;
            let pair = Arc::new(PoolPair::new());
            let producer_handle = if cfg.collaboration {
                let pair = Arc::clone(&pair);
                let cfg2 = cfg.clone();
                let base2 = base_rng.clone();
                let counters2 = Arc::clone(&counters);
                Some(scope.spawn(move || {
                    let mut buf = SamplePool::new();
                    for pool_idx in start_pool..num_pools {
                        fill_pool_counted(
                            sampling_ref, &cfg2, &base2, &counters2, pool_idx, pool_size, &mut buf,
                        );
                        match pair.publish(buf) {
                            Some(b) => buf = b,
                            // consumer abandoned the run (error path)
                            None => return,
                        }
                    }
                    pair.finish();
                }))
            } else {
                None
            };

            // ---- consumption: episodes over each pool ----
            let mut runner = EpisodeRunner {
                cfg: &cfg,
                parts: &parts,
                sched: &sched,
                artifact: artifact.as_ref(),
                counters: &counters,
                transport,
                engine: TransferEngine::new(
                    &sched,
                    cfg.residency,
                    cfg.fix_context,
                    cfg.residency_limits(),
                ),
                grid: BlockGrid::new_empty(num_parts),
                next_grid: BlockGrid::new_empty(num_parts),
                grid_prefilled: false,
                total_samples,
                samples_planned: resume_planned,
                in_flight: Vec::new(),
                recovery: cfg.recovery_enabled().then(|| {
                    RecoveryState::new(
                        cfg.num_workers,
                        init_worker_rngs.clone(),
                        cfg.max_worker_retries,
                    )
                }),
                stray_syncs: Vec::new(),
            };

            // Consumption is fallible (fail-loud residency protocol, worker
            // errors); its error must not propagate before the producer is
            // unblocked, or the scope's implicit join would hang forever on
            // a producer parked in PoolPair::publish.
            let consume_res: Result<()> = (|| {
                if cfg.collaboration {
                    // the first pool is taken here; every later one is
                    // prefetched (taken + redistributed) during the
                    // previous pool's final fence drain
                    let mut next = pair.take();
                    while let Some(pool) = next.take() {
                        let (drained, prefetched) = runner.consume_pool(
                            &mut store,
                            pool,
                            Some(&pair),
                            &mut samples_done,
                            &mut loss_curve,
                        )?;
                        // hand the drained allocation back to the producer
                        pair.recycle(drained);
                        pools_done += 1;
                        let flow = observe_pool(
                            &mut observer,
                            &mut runner,
                            &mut store,
                            &cfg,
                            num_edges,
                            num_parts,
                            pool_size,
                            pools_done,
                            samples_done,
                            fault_path.as_ref().map(|_| &mut fault_stash),
                        )?;
                        if flow == TrainFlow::Stop {
                            break;
                        }
                        next = prefetched;
                    }
                } else {
                    let mut buf = SamplePool::new();
                    for pool_idx in start_pool..num_pools {
                        fill_pool_counted(
                            sampling_ref, &cfg, &base_rng, &counters, pool_idx, pool_size, &mut buf,
                        );
                        let (drained, _) = runner.consume_pool(
                            &mut store,
                            std::mem::take(&mut buf),
                            None,
                            &mut samples_done,
                            &mut loss_curve,
                        )?;
                        buf = drained;
                        pools_done += 1;
                        let flow = observe_pool(
                            &mut observer,
                            &mut runner,
                            &mut store,
                            &cfg,
                            num_edges,
                            num_parts,
                            pool_size,
                            pools_done,
                            samples_done,
                            fault_path.as_ref().map(|_| &mut fault_stash),
                        )?;
                        if flow == TrainFlow::Stop {
                            break;
                        }
                    }
                }
                // pull worker-resident partitions back into the store
                runner.sync_residents(&mut store).map(|_| ())
            })();

            // Unblock a parked producer — on the error path AND after an
            // observer's early stop, pools it is still filling will never
            // be taken, so its publish must return None. After a normal
            // completion the producer has already exited; close is a no-op.
            pair.close();
            // Stop the workers through the transport: the local one sends
            // Stop down every channel; the socket one additionally
            // collects each worker's BYE ledger and verifies it against
            // its own per-connection byte counts.
            let shutdown_res = runner.transport.shutdown();
            if let Some(h) = producer_handle {
                h.join().map_err(|_| anyhow::anyhow!("producer panicked"))?;
            }
            let mut worker_res: Result<()> = Ok(());
            for h in handles {
                let r = h.join().map_err(|_| anyhow::anyhow!("worker panicked"))?;
                if worker_res.is_ok() {
                    worker_res = r;
                }
            }
            // A worker-thread error (backend construction — run_job errors
            // travel through the result channel instead and land in
            // consume_res) is the root cause of any subsequent
            // channel-disconnect error the consumption loop saw: surface
            // it first so "worker channel closed" never masks it; a
            // shutdown/ledger error likewise only matters on an otherwise
            // clean run.
            worker_res?;
            consume_res?;
            shutdown_res
        });
        let report = match scope_res {
            Ok(r) => r,
            Err(e) => {
                // checkpoint-on-fault: recovery is exhausted (or off) and
                // the run is dying — cut a .gvck at the last completed
                // pool boundary first, so at most one pool is lost
                if let (Some(path), Some(ck)) = (&fault_path, &fault_stash) {
                    match save_checkpoint(&ck.state(), path) {
                        Ok(()) => eprintln!(
                            "coordinator: fault checkpoint cut at pool boundary {} -> {}",
                            ck.pools_done,
                            path.display()
                        ),
                        Err(save_err) => eprintln!(
                            "coordinator: fault checkpoint to {} failed: {save_err:#}",
                            path.display()
                        ),
                    }
                }
                return Err(e);
            }
        };

        train_sw.stop();
        let snapshot = counters.snapshot();
        // Close the loop on the wire ledger: what crossed the socket must
        // be exactly what the transfer engine planned and scattered.
        if let Some(r) = report {
            anyhow::ensure!(
                r.bytes_up == snapshot.bytes_to_device,
                "transport shipped {} payload bytes to workers but the transfer engine \
                 gathered {} (bytes_to_device)",
                r.bytes_up,
                snapshot.bytes_to_device
            );
            anyhow::ensure!(
                r.bytes_down == snapshot.bytes_from_device,
                "transport received {} payload bytes from workers but the coordinator \
                 scattered {} (bytes_from_device)",
                r.bytes_down,
                snapshot.bytes_from_device
            );
        }
        self.last_transport = report;
        let stats = TrainStats {
            train_secs: train_sw.secs(),
            preprocess_secs: prep.secs(),
            final_loss: loss_curve.last().copied().unwrap_or(f32::NAN),
            loss_curve,
            counters: snapshot,
        };
        Ok(TrainResult { embeddings: store, stats })
    }
}

/// The coordinator's episode loop over one training run: owns the
/// transfer engine, the recycled block grid and the dispatch/drain
/// bookkeeping of the pipelined wave protocol.
struct EpisodeRunner<'a> {
    cfg: &'a TrainConfig,
    parts: &'a Partitioning,
    sched: &'a EpisodeSchedule,
    artifact: Option<&'a ArtifactMeta>,
    counters: &'a Counters,
    /// Delivery seam: in-process channels ([`LocalTransport`]), TCP
    /// streams ([`SocketTransport`]) or a fault-injection decorator —
    /// the episode loop is identical over all of them.
    transport: Box<dyn Transport>,
    engine: TransferEngine,
    grid: BlockGrid,
    /// Double buffer for the overlapped pool refill: while the LAST
    /// episode group's in-flight waves drain, a helper thread takes the
    /// next pool from the [`PoolPair`] and redistributes it into this
    /// grid (see [`Self::fence_with_prefetch`]), so the refill no longer
    /// runs sequentially on the main thread between pools.
    next_grid: BlockGrid,
    /// `next_grid` holds the redistribution of the pool
    /// [`Self::consume_pool`] returned last time.
    grid_prefilled: bool,
    total_samples: u64,
    /// Positive samples *dispatched* so far. Drives the LR schedule: the
    /// trained count of a job equals its block length, so this matches
    /// the result-side count at every wave boundary while being available
    /// at send time — pipelined and serial dispatch see identical LRs.
    samples_planned: u64,
    /// Blocks in flight: (worker, vid, cid) of every dispatched job whose
    /// result has not been absorbed. A set rather than a counter so a
    /// duplicated or fabricated result (a misbehaving transport) is a
    /// pointed error instead of a silent double-scatter + counter
    /// underflow; the worker index lets recovery drop a dead slot's
    /// entries precisely.
    in_flight: Vec<(usize, usize, usize)>,
    /// Worker-failure recovery bookkeeping; `None` keeps the PR-7
    /// fail-loud behavior bit-for-bit (`TrainConfig::recovery_enabled`).
    recovery: Option<RecoveryState>,
    /// Sync replies that arrived while a fence-time recovery was folding
    /// a dead slot's journal (the fold's serial wait drains the shared
    /// reply stream); [`Self::sync_residents`] consumes them first.
    stray_syncs: Vec<SyncReply>,
}

/// How a replayed job result whose original was already absorbed (before
/// its worker died) is disposed of on second delivery.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum DiscardMode {
    /// Replacement replay: the payloads are already in the host store —
    /// recycle the buffers and count the wire bytes, touch nothing else.
    Drop,
    /// Fold replay: the dead worker's kept outputs lived only on its
    /// device — scatter the replayed payloads to regenerate them, but
    /// leave the sample counters alone (the job already counted once).
    ScatterOnly,
}

/// Worker-failure recovery state (`max_worker_retries > 0`): the
/// in-flight shipment journal plus per-slot replay bases. The journal's
/// scope is the current episode group: every group fence syncs all
/// resident partitions home (making the host store the authoritative
/// replay base), records each worker's RNG, and clears the journal — so
/// a dead slot's work since the fence can be regenerated bitwise from
/// `base_rng` + the journaled shipments.
struct RecoveryState {
    /// Per slot, the jobs dispatched since the last group fence, in
    /// dispatch order. Completed entries stay (flagged `done`): their
    /// kept outputs existed only on the dead device, so a replacement or
    /// fold must replay them too to rebuild that state.
    journal: Vec<Vec<JournalEntry>>,
    /// Each slot's RNG stream state at the last group fence (initially
    /// the stream's start) — where a journal replay begins.
    base_rng: Vec<[u64; 4]>,
    /// Slots permanently folded onto survivors.
    folded: Vec<bool>,
    /// A folded slot's RNG chain, advanced by every job trained on its
    /// behalf (survivors run takeover jobs with this stream; their own
    /// streams never move for folded work).
    folded_rng: Vec<[u64; 4]>,
    /// Round-robin cursor over survivors for folded work.
    next_survivor: usize,
    /// Replayed jobs whose original result was already absorbed; the
    /// second delivery is consumed here instead of `in_flight`.
    pending_discards: Vec<(usize, usize, DiscardMode)>,
    /// Distinct worker failures this run may still absorb before giving
    /// up (checkpoint-on-fault, then the original error).
    recoveries_left: u64,
}

impl RecoveryState {
    fn new(n: usize, init_rngs: Vec<[u64; 4]>, budget: u64) -> Self {
        RecoveryState {
            journal: (0..n).map(|_| Vec::new()).collect(),
            base_rng: init_rngs,
            folded: vec![false; n],
            folded_rng: vec![[0u64; 4]; n],
            next_survivor: 0,
            pending_discards: Vec::new(),
            recoveries_left: budget,
        }
    }
}

impl EpisodeRunner<'_> {
    /// Run all episode groups over one pool; returns the drained pool
    /// for recycling, plus — when `prefetch` is given — the *next* pool,
    /// taken and redistributed into [`Self::next_grid`] while the last
    /// group's in-flight waves drained (the overlapped refill; pass the
    /// returned pool back in on the next call). `None` from the prefetch
    /// means the producer finished the stream.
    fn consume_pool(
        &mut self,
        store: &mut EmbeddingStore,
        pool: SamplePool,
        prefetch: Option<&PoolPair>,
        samples_done: &mut u64,
        loss_curve: &mut Vec<f32>,
    ) -> Result<(SamplePool, Option<SamplePool>)> {
        self.counters.add(&self.counters.samples_generated, pool.len() as u64);
        // In collaboration mode the producer's sampler threads are filling
        // the next pool while we redistribute this one; halve the refill
        // shards so the boundary doesn't burst to 2x the sampler-core
        // budget. (Thread count never changes the refill result — the
        // merge is order-preserving — so this is purely a scheduling
        // choice.)
        let refill_threads = if self.cfg.collaboration {
            (self.cfg.num_samplers / 2).max(1)
        } else {
            self.cfg.num_samplers
        };
        if self.grid_prefilled {
            // `pool` was already redistributed into next_grid during the
            // previous pool's final drain — just swap the buffers in
            std::mem::swap(&mut self.grid, &mut self.next_grid);
            self.grid_prefilled = false;
        } else {
            self.grid
                .refill(&pool, self.parts, refill_threads, &mut self.engine.block_spare);
        }
        let sched = self.sched;
        let groups = sched.ordered_groups();
        let mut prefetched: Option<SamplePool> = None;
        for (gi, &g) in groups.iter().enumerate() {
            let mut ep_loss = 0.0f64;
            let mut ep_trained = 0u64;
            for w in 0..sched.waves_per_group() {
                let lr = self.cfg.lr
                    * (1.0 - self.samples_planned as f32 / self.total_samples as f32).max(1e-4);
                for a in sched.wave(g, w) {
                    // a failed dispatch names a dead worker: recover
                    // (replace or fold) and keep going, or die loud
                    if let Err(e) =
                        self.dispatch(store, &a, lr, &mut ep_loss, &mut ep_trained, samples_done)
                    {
                        self.recover(store, e, &mut ep_loss, &mut ep_trained, samples_done)?;
                    }
                }
                if self.cfg.pipeline_transfers {
                    // prefetch mode: scatter whatever has already finished
                    // and keep dispatching — the group fence below is the
                    // only blocking wait
                    loop {
                        match self.try_recv_result() {
                            Ok(Some(res)) => self.absorb(
                                store, res, &mut ep_loss, &mut ep_trained, samples_done,
                            )?,
                            Ok(None) => break,
                            Err(e) => self.recover(
                                store, e, &mut ep_loss, &mut ep_trained, samples_done,
                            )?,
                        }
                    }
                } else {
                    // serial (PR-2) dispatch: one wave in flight at a time
                    while !self.in_flight.is_empty() {
                        match self.recv_result() {
                            Ok(res) => self.absorb(
                                store, res, &mut ep_loss, &mut ep_trained, samples_done,
                            )?,
                            Err(e) => self.recover(
                                store, e, &mut ep_loss, &mut ep_trained, samples_done,
                            )?,
                        }
                    }
                }
            }
            // group fence: the next group's gathers overlap this group's
            // scatters, so every result must land before moving on. At
            // the LAST group of the pool the fence drain is dead time on
            // this thread — overlap it with taking + redistributing the
            // next pool (pure scheduling: dispatch order, absorb
            // commutativity and the LR schedule are all untouched, so
            // embeddings stay bitwise-identical — pinned in
            // rust/tests/pipeline_equivalence.rs).
            match prefetch.filter(|_| gi + 1 == groups.len()) {
                Some(pair) => {
                    prefetched = self.fence_with_prefetch(
                        store,
                        pair,
                        refill_threads,
                        &mut ep_loss,
                        &mut ep_trained,
                        samples_done,
                    )?;
                }
                None => {
                    while !self.in_flight.is_empty() {
                        match self.recv_result() {
                            Ok(res) => self.absorb(
                                store, res, &mut ep_loss, &mut ep_trained, samples_done,
                            )?,
                            Err(e) => self.recover(
                                store, e, &mut ep_loss, &mut ep_trained, samples_done,
                            )?,
                        }
                    }
                }
            }
            self.group_fence(store)?;
            self.counters.add(&self.counters.episodes, 1);
            if ep_trained > 0 {
                loss_curve.push((ep_loss / ep_trained as f64) as f32);
            }
            if self.cfg.log_every > 0 && loss_curve.len() % self.cfg.log_every == 0 {
                eprintln!(
                    "episode {} loss {:.4} ({}/{} samples)",
                    loss_curve.len(),
                    loss_curve.last().unwrap(),
                    samples_done,
                    self.total_samples
                );
            }
        }
        Ok((pool, prefetched))
    }

    /// The final group fence of a pool, overlapped with the next pool's
    /// refill: a helper thread blocks on [`PoolPair::take`] and
    /// redistributes the pool it gets into [`Self::next_grid`], while
    /// this thread drains the in-flight results. The block free-list is
    /// handed to the helper wholesale (buffers absorbed during the drain
    /// simply start a fresh list — buffer identity never affects trained
    /// values), and comes back merged afterwards.
    fn fence_with_prefetch(
        &mut self,
        store: &mut EmbeddingStore,
        pair: &PoolPair,
        refill_threads: usize,
        ep_loss: &mut f64,
        ep_trained: &mut u64,
        samples_done: &mut u64,
    ) -> Result<Option<SamplePool>> {
        let parts = self.parts;
        let mut grid =
            std::mem::replace(&mut self.next_grid, BlockGrid::new_empty(parts.num_parts()));
        let mut spare = std::mem::take(&mut self.engine.block_spare);
        let (joined, drain) = std::thread::scope(|scope| {
            let handle = scope.spawn(move || match pair.take() {
                Some(pool) => {
                    grid.refill(&pool, parts, refill_threads, &mut spare);
                    (Some(pool), grid, spare)
                }
                None => (None, grid, spare),
            });
            let mut drain: Result<()> = Ok(());
            while !self.in_flight.is_empty() {
                let step = match self.recv_result() {
                    Ok(res) => self.absorb(store, res, ep_loss, ep_trained, samples_done),
                    Err(e) => Err(e),
                };
                if let Err(e) = step {
                    // a dead worker is recovered in place (replace or
                    // fold) and the drain continues; anything else ends
                    // it. Either way the helper unblocks on its own: the
                    // producer either publishes (take returns a pool) or
                    // finishes (take returns None).
                    if let Err(e2) = self.recover(store, e, ep_loss, ep_trained, samples_done) {
                        drain = Err(e2);
                        break;
                    }
                }
            }
            (handle.join(), drain)
        });
        let (pool, grid, mut spare) =
            joined.map_err(|_| anyhow::anyhow!("prefetch refill thread panicked"))?;
        self.next_grid = grid;
        self.engine.block_spare.append(&mut spare);
        self.grid_prefilled = pool.is_some();
        drain?;
        Ok(pool)
    }

    /// Gather (or residency-elide) one assignment's partitions and send
    /// the job to its worker. With recovery on, the job is journaled
    /// before the send, so a send that kills the worker replays the job
    /// along with the rest of the slot's journal.
    fn dispatch(
        &mut self,
        store: &mut EmbeddingStore,
        a: &Assignment,
        lr: f32,
        ep_loss: &mut f64,
        ep_trained: &mut u64,
        samples_done: &mut u64,
    ) -> Result<()> {
        let block = self.grid.take_block(a.vid, a.cid);
        self.samples_planned += block.len() as u64;
        if self.recovery.as_ref().is_some_and(|r| r.folded[a.worker]) {
            // the slot was folded onto survivors: same version/cursor
            // trajectory, forced upload, serial takeover dispatch
            return self.dispatch_folded(store, a, lr, block, ep_loss, ep_trained, samples_done);
        }
        let (vplan, cplan) = self.engine.plan(a);
        let t0 = std::time::Instant::now();
        let vertex = self.gather(store, Matrix::Vertex, a.vid, vplan);
        let context = self.gather(store, Matrix::Context, a.cid, cplan);
        self.counters
            .add(&self.counters.gather_nanos, t0.elapsed().as_nanos() as u64);
        if self.recovery.is_some() {
            let entry = self.journal_entry(store, a, lr, &block, &vertex, &context);
            self.recovery.as_mut().unwrap().journal[a.worker].push(entry);
        }
        self.transport.send(
            a.worker,
            JobMsg::Train(Job {
                vid: a.vid,
                cid: a.cid,
                block,
                vertex,
                context,
                lr,
                takeover: None,
            }),
        )?;
        self.in_flight.push((a.worker, a.vid, a.cid));
        Ok(())
    }

    /// Build the journal record of a job about to be dispatched: block +
    /// transfer flags, plus a payload snapshot for the group's FIRST
    /// touch of each partition on that worker — the replay base; later
    /// touches chain off the in-journal predecessor's on-device output.
    /// An elided first touch snapshots from the host store, which is
    /// current at every group fence thanks to the recovery-mode resident
    /// sync.
    fn journal_entry(
        &self,
        store: &EmbeddingStore,
        a: &Assignment,
        lr: f32,
        block: &[(i32, i32)],
        vertex: &Shipment,
        context: &Shipment,
    ) -> JournalEntry {
        JournalEntry {
            vid: a.vid,
            cid: a.cid,
            lr,
            block: block.to_vec(),
            vertex: self.journal_shipment(store, Matrix::Vertex, a.vid, a.worker, vertex),
            context: self.journal_shipment(store, Matrix::Context, a.cid, a.worker, context),
            done: false,
        }
    }

    fn journal_shipment(
        &self,
        store: &EmbeddingStore,
        matrix: Matrix,
        pid: usize,
        worker: usize,
        ship: &Shipment,
    ) -> JournalShipment {
        let rec = self.recovery.as_ref().expect("journal without recovery");
        let data = match &ship.data {
            Some(d) => Some(d.clone()),
            None => {
                let prior_touch = rec.journal[worker].iter().any(|e| match matrix {
                    Matrix::Vertex => e.vid == pid,
                    Matrix::Context => e.cid == pid,
                });
                if prior_touch {
                    // chains off the predecessor's kept on-device output;
                    // a replay regenerates it by replaying the
                    // predecessor first
                    None
                } else {
                    // elided first touch: the resident copy equals the
                    // host rows (synced at the last fence) — snapshot them
                    let cap = crate::gpu::planned_capacity(
                        self.cfg,
                        self.artifact,
                        self.parts.part_size(pid),
                    );
                    let mut buf = Vec::new();
                    store.gather_partition(self.parts, pid, cap, matrix, &mut buf);
                    Some(buf)
                }
            }
        };
        JournalShipment { data, src_version: ship.src_version, keep: ship.keep }
    }

    fn gather(
        &mut self,
        store: &EmbeddingStore,
        matrix: Matrix,
        pid: usize,
        plan: ShipPlan,
    ) -> Shipment {
        let cap =
            crate::gpu::planned_capacity(self.cfg, self.artifact, self.parts.part_size(pid));
        let data = if plan.upload {
            let mut buf = self.engine.take_f32();
            store.gather_partition(self.parts, pid, cap, matrix, &mut buf);
            self.counters
                .add(&self.counters.bytes_to_device, (buf.len() * 4) as u64);
            Some(buf)
        } else {
            // the worker already holds the current version resident
            self.counters.add(&self.counters.residency_hits, 1);
            self.counters
                .add(&self.counters.bytes_saved, (cap * self.cfg.dim * 4) as u64);
            None
        };
        Shipment { data, src_version: plan.src_version, keep: plan.keep }
    }

    /// Scatter one job result into the store and recycle its buffers.
    /// Rejects results for blocks that are not in flight — a duplicated
    /// or fabricated delivery must fail loud, never double-scatter.
    fn absorb(
        &mut self,
        store: &mut EmbeddingStore,
        res: JobResult,
        ep_loss: &mut f64,
        ep_trained: &mut u64,
        samples_done: &mut u64,
    ) -> Result<()> {
        let res = match self.discard_replayed(store, res)? {
            Some(res) => res,
            None => return Ok(()), // a replay's second delivery, disposed of
        };
        let slot = self
            .in_flight
            .iter()
            .position(|&(_, v, c)| v == res.vid && c == res.cid)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "result for block ({}, {}) which is not in flight — duplicated or \
                     corrupted delivery",
                    res.vid,
                    res.cid
                )
            })?;
        self.in_flight.swap_remove(slot);
        // the journal keeps completed entries (their kept outputs live
        // only on the worker's device): flag, don't pop
        if let Some(rec) = &mut self.recovery {
            if res.worker < rec.journal.len() {
                if let Some(e) = rec.journal[res.worker]
                    .iter_mut()
                    .find(|e| !e.done && e.vid == res.vid && e.cid == res.cid)
                {
                    e.done = true;
                }
            }
        }
        let t0 = std::time::Instant::now();
        if let Some(v) = res.vertex {
            store.scatter_partition(self.parts, res.vid, Matrix::Vertex, &v);
            self.counters
                .add(&self.counters.bytes_from_device, (v.len() * 4) as u64);
            self.engine.put_f32(v);
        }
        if let Some(c) = res.context {
            store.scatter_partition(self.parts, res.cid, Matrix::Context, &c);
            self.counters
                .add(&self.counters.bytes_from_device, (c.len() * 4) as u64);
            self.engine.put_f32(c);
        }
        self.counters
            .add(&self.counters.scatter_nanos, t0.elapsed().as_nanos() as u64);
        self.engine.put_block(res.block);
        // counted here (not worker-side) so local and remote workers feed
        // the same ledger — res.trained is the job's real sample count
        self.counters.add(&self.counters.samples_trained, res.trained);
        *ep_loss += res.loss as f64 * res.trained as f64;
        *ep_trained += res.trained;
        *samples_done += res.trained;
        Ok(())
    }

    /// Recovery: a replayed job whose original result was already
    /// absorbed delivers a second result — dispose of it per its
    /// [`DiscardMode`] instead of the in-flight path. Returns the result
    /// back when it is a first (normal) delivery.
    fn discard_replayed(
        &mut self,
        store: &mut EmbeddingStore,
        res: JobResult,
    ) -> Result<Option<JobResult>> {
        let mode = match &mut self.recovery {
            Some(rec) => {
                match rec
                    .pending_discards
                    .iter()
                    .position(|&(v, c, _)| v == res.vid && c == res.cid)
                {
                    Some(i) => rec.pending_discards.swap_remove(i).2,
                    None => return Ok(Some(res)),
                }
            }
            None => return Ok(Some(res)),
        };
        // either way the payload crossed the wire: the engine-side ledger
        // counts it so the transport ledger still balances
        let t0 = std::time::Instant::now();
        if let Some(v) = res.vertex {
            if mode == DiscardMode::ScatterOnly {
                store.scatter_partition(self.parts, res.vid, Matrix::Vertex, &v);
            }
            self.counters
                .add(&self.counters.bytes_from_device, (v.len() * 4) as u64);
            self.engine.put_f32(v);
        }
        if let Some(c) = res.context {
            if mode == DiscardMode::ScatterOnly {
                store.scatter_partition(self.parts, res.cid, Matrix::Context, &c);
            }
            self.counters
                .add(&self.counters.bytes_from_device, (c.len() * 4) as u64);
            self.engine.put_f32(c);
        }
        self.counters
            .add(&self.counters.scatter_nanos, t0.elapsed().as_nanos() as u64);
        self.engine.put_block(res.block);
        Ok(None)
    }

    /// Blocking receive of one training result.
    fn recv_result(&mut self) -> Result<JobResult> {
        loop {
            match self.transport.recv()? {
                Reply::Job(r) => return Ok(r),
                Reply::Synced(_) => anyhow::bail!("unexpected sync reply mid-episode"),
                Reply::Pong => {} // stray liveness ack
            }
        }
    }

    /// Non-blocking receive (pipelined mode's opportunistic drain).
    fn try_recv_result(&mut self) -> Result<Option<JobResult>> {
        loop {
            match self.transport.try_recv()? {
                Some(Reply::Job(r)) => return Ok(Some(r)),
                Some(Reply::Synced(_)) => anyhow::bail!("unexpected sync reply mid-episode"),
                Some(Reply::Pong) => {}
                None => return Ok(None),
            }
        }
    }

    // ------------------------------------------------------------------
    // Worker-failure recovery (ISSUE 8): journal replay, rejoin, fold.
    // ------------------------------------------------------------------

    /// Recovery entry point, called with the error a dispatch/drain step
    /// produced. When recovery is off, the transport names no failed
    /// slot, or the budget is exhausted, the error propagates (the PR-7
    /// fail-loud contract); otherwise the dead slot is either re-staffed
    /// from the rejoin listener and its journal replayed to the
    /// replacement, or — when no replacement dials in within the rejoin
    /// window — folded onto the survivors. Both paths are bitwise: the
    /// journal holds every input and the dead slot's RNG base, so the
    /// lost work is regenerated exactly.
    fn recover(
        &mut self,
        store: &mut EmbeddingStore,
        err: anyhow::Error,
        ep_loss: &mut f64,
        ep_trained: &mut u64,
        samples_done: &mut u64,
    ) -> Result<()> {
        if self.recovery.is_none() {
            return Err(err);
        }
        let Some(slot) = self.transport.failed_worker() else {
            // not a worker death (absorb rejection, logic error, ...) —
            // never paper over it
            return Err(err);
        };
        {
            let rec = self.recovery.as_mut().unwrap();
            if rec.folded[slot] {
                return Err(err); // a folded slot cannot fail again
            }
            if rec.recoveries_left == 0 {
                return Err(err.context(format!(
                    "worker-failure recovery budget exhausted (max_worker_retries = {})",
                    self.cfg.max_worker_retries
                )));
            }
            rec.recoveries_left -= 1;
        }
        eprintln!("coordinator: worker {slot} failed: {err:#}");
        // the dead slot's in-flight jobs are lost with it; the journal
        // replays them below
        self.in_flight.retain(|&(w, _, _)| w != slot);
        let base = self.recovery.as_ref().unwrap().base_rng[slot];
        // hold the slot open for a replacement, with capped backoff
        let window = Duration::from_secs(self.cfg.rejoin_window_secs);
        let start = Instant::now();
        let mut backoff = Duration::from_millis(100);
        let mut replaced = self.transport.try_replace(slot, base)?;
        while !replaced && start.elapsed() < window {
            std::thread::sleep(backoff.min(window.saturating_sub(start.elapsed())));
            backoff = (backoff * 2).min(Duration::from_secs(2));
            replaced = self.transport.try_replace(slot, base)?;
        }
        if replaced {
            self.replay_to_replacement(slot)
        } else {
            let survivors = {
                let rec = self.recovery.as_ref().unwrap();
                (0..self.transport.num_workers())
                    .filter(|&w| w != slot && !rec.folded[w])
                    .count()
            };
            anyhow::ensure!(
                survivors > 0,
                "worker {slot} failed with no surviving workers to fold its work onto"
            );
            eprintln!(
                "coordinator: no replacement for worker {slot} within {window:?} — folding \
                 its {} journaled job(s) onto {survivors} survivor(s)",
                self.recovery.as_ref().unwrap().journal[slot].len()
            );
            self.transport.mark_dead(slot);
            {
                let rec = self.recovery.as_mut().unwrap();
                rec.folded[slot] = true;
                rec.folded_rng[slot] = base;
            }
            self.engine.forget_worker(slot);
            self.fold_journal(store, slot, ep_loss, ep_trained, samples_done)
        }
    }

    /// A replacement took the dead slot (same fingerprint, next
    /// generation, its RNG seeded at the slot's replay base): rebuild
    /// the device state by re-sending the slot's journal verbatim.
    /// Completed entries are replayed too — their kept outputs existed
    /// only on the dead device — and their second results are dropped on
    /// delivery ([`DiscardMode::Drop`]).
    fn replay_to_replacement(&mut self, slot: usize) -> Result<()> {
        // the replacement starts with an empty cache; the engine's
        // residency view is rebuilt entry by entry below, exactly as the
        // original plans recorded it
        self.engine.forget_worker(slot);
        let n = self.recovery.as_ref().unwrap().journal[slot].len();
        eprintln!("coordinator: worker {slot} replaced — re-dispatching {n} journaled job(s)");
        for k in 0..n {
            let (vid, cid, lr, done, block, vertex, context) = {
                let e = &self.recovery.as_ref().unwrap().journal[slot][k];
                (
                    e.vid,
                    e.cid,
                    e.lr,
                    e.done,
                    e.block.clone(),
                    Shipment {
                        data: e.vertex.data.clone(),
                        src_version: e.vertex.src_version,
                        keep: e.vertex.keep,
                    },
                    Shipment {
                        data: e.context.data.clone(),
                        src_version: e.context.src_version,
                        keep: e.context.keep,
                    },
                )
            };
            // re-shipped payloads cross the wire again: count them on the
            // engine side so the transport ledger still balances
            let replayed = vertex.data.as_ref().map_or(0, |d| d.len())
                + context.data.as_ref().map_or(0, |d| d.len());
            self.counters
                .add(&self.counters.bytes_to_device, (replayed * 4) as u64);
            for (matrix, pid, ship) in
                [(Matrix::Vertex, vid, &vertex), (Matrix::Context, cid, &context)]
            {
                if ship.keep {
                    self.engine.set_resident(slot, matrix, pid, ship.src_version + 1);
                } else {
                    self.engine.drop_residency(slot, matrix, pid);
                }
            }
            self.transport.send(
                slot,
                JobMsg::Train(Job { vid, cid, block, vertex, context, lr, takeover: None }),
            )?;
            if done {
                self.recovery
                    .as_mut()
                    .unwrap()
                    .pending_discards
                    .push((vid, cid, DiscardMode::Drop));
            } else {
                self.in_flight.push((slot, vid, cid));
            }
        }
        Ok(())
    }

    /// No replacement arrived: replay the dead slot's journal onto the
    /// survivors, serially. Each job carries a [`Takeover`] (the dead
    /// slot's RNG chain + chunk size), so the survivor computes bitwise
    /// the result the dead worker would have; payloads come from the
    /// journal snapshot or — for chained entries — the host store, which
    /// the serial replay-and-scatter keeps current.
    fn fold_journal(
        &mut self,
        store: &mut EmbeddingStore,
        slot: usize,
        ep_loss: &mut f64,
        ep_trained: &mut u64,
        samples_done: &mut u64,
    ) -> Result<()> {
        let n = self.recovery.as_ref().unwrap().journal[slot].len();
        for k in 0..n {
            let (vid, cid, lr, done, block, vdata, vver, cdata, cver) = {
                let e = &self.recovery.as_ref().unwrap().journal[slot][k];
                (
                    e.vid,
                    e.cid,
                    e.lr,
                    e.done,
                    e.block.clone(),
                    e.vertex.data.clone(),
                    e.vertex.src_version,
                    e.context.data.clone(),
                    e.context.src_version,
                )
            };
            let vertex = self.folded_payload(store, Matrix::Vertex, vid, vver, vdata);
            let context = self.folded_payload(store, Matrix::Context, cid, cver, cdata);
            self.fold_dispatch(
                store, slot, vid, cid, lr, block, vertex, context, done, ep_loss, ep_trained,
                samples_done,
            )?;
        }
        Ok(())
    }

    /// Payload of one folded replay: the journal snapshot when one was
    /// taken, else a fresh gather — correct because folded replay is
    /// serial and scatters as it goes, so the host rows are exactly the
    /// predecessor entry's output when a chained entry comes up. Folded
    /// traffic is always a full upload with no keep: the dead slot has
    /// no device to cache on, and the survivor's own residency must stay
    /// untouched.
    fn folded_payload(
        &mut self,
        store: &EmbeddingStore,
        matrix: Matrix,
        pid: usize,
        src_version: u64,
        snapshot: Option<Vec<f32>>,
    ) -> Shipment {
        let data = match snapshot {
            Some(d) => d,
            None => {
                let cap = crate::gpu::planned_capacity(
                    self.cfg,
                    self.artifact,
                    self.parts.part_size(pid),
                );
                let mut buf = self.engine.take_f32();
                store.gather_partition(self.parts, pid, cap, matrix, &mut buf);
                buf
            }
        };
        self.counters
            .add(&self.counters.bytes_to_device, (data.len() * 4) as u64);
        Shipment { data: Some(data), src_version, keep: false }
    }

    /// Ship one folded job to a survivor and wait for its result (the
    /// next folded job's input may be this one's output). Survivor
    /// results arriving in between are absorbed normally; sync replies
    /// (a fence-time fold) are stashed for [`Self::sync_residents`].
    #[allow(clippy::too_many_arguments)]
    fn fold_dispatch(
        &mut self,
        store: &mut EmbeddingStore,
        dead: usize,
        vid: usize,
        cid: usize,
        lr: f32,
        block: Vec<(i32, i32)>,
        vertex: Shipment,
        context: Shipment,
        done: bool,
        ep_loss: &mut f64,
        ep_trained: &mut u64,
        samples_done: &mut u64,
    ) -> Result<()> {
        let target = self.next_survivor(dead)?;
        let takeover = Takeover {
            rng: self.recovery.as_ref().unwrap().folded_rng[dead],
            chunk_samples: (self.cfg.batch_size * self.cfg.worker_capacity(dead)) as u32,
        };
        self.transport.send(
            target,
            JobMsg::Train(Job { vid, cid, block, vertex, context, lr, takeover: Some(takeover) }),
        )?;
        if done {
            self.recovery
                .as_mut()
                .unwrap()
                .pending_discards
                .push((vid, cid, DiscardMode::ScatterOnly));
        } else {
            self.in_flight.push((target, vid, cid));
        }
        loop {
            match self.transport.recv()? {
                Reply::Job(res) => {
                    let mine = res.vid == vid && res.cid == cid;
                    let rng = res.rng_state;
                    self.absorb(store, res, ep_loss, ep_trained, samples_done)?;
                    if mine {
                        // chain the dead slot's stream through its
                        // replayed job
                        self.recovery.as_mut().unwrap().folded_rng[dead] = rng;
                        return Ok(());
                    }
                }
                Reply::Synced(s) => self.stray_syncs.push(s),
                Reply::Pong => {}
            }
        }
    }

    /// Round-robin over live, unfolded slots for folded work. The choice
    /// never affects trained bytes: a takeover job runs with the dead
    /// slot's RNG and chunk size wherever it lands, and its forced
    /// upload/no-keep transfer leaves the survivor's residency untouched.
    fn next_survivor(&mut self, dead: usize) -> Result<usize> {
        let n = self.transport.num_workers();
        let rec = self.recovery.as_mut().unwrap();
        for _ in 0..n {
            let cand = rec.next_survivor % n;
            rec.next_survivor += 1;
            if cand != dead && !rec.folded[cand] {
                return Ok(cand);
            }
        }
        anyhow::bail!("worker {dead} failed with no surviving workers to fold its work onto")
    }

    /// A scheduled assignment whose slot was folded: advance the
    /// engine's version/cursor state exactly as a live dispatch would
    /// (the LR and version trajectories must not notice the fold), force
    /// upload/no-keep, and run it as a takeover job on a survivor.
    #[allow(clippy::too_many_arguments)]
    fn dispatch_folded(
        &mut self,
        store: &mut EmbeddingStore,
        a: &Assignment,
        lr: f32,
        block: Vec<(i32, i32)>,
        ep_loss: &mut f64,
        ep_trained: &mut u64,
        samples_done: &mut u64,
    ) -> Result<()> {
        let (vplan, cplan) = self.engine.plan_folded(a);
        let t0 = std::time::Instant::now();
        let vertex = self.gather(store, Matrix::Vertex, a.vid, vplan);
        let context = self.gather(store, Matrix::Context, a.cid, cplan);
        self.counters
            .add(&self.counters.gather_nanos, t0.elapsed().as_nanos() as u64);
        self.fold_dispatch(
            store, a.worker, a.vid, a.cid, lr, block, vertex, context, false, ep_loss,
            ep_trained, samples_done,
        )
    }

    /// Recovery bookkeeping at every group fence: pull all resident
    /// partitions home (the host store becomes the authoritative replay
    /// base), refresh each slot's journal RNG base, and clear the
    /// journal — "dispatched since the last fence" is exactly what a
    /// dead slot needs replayed. No-op when recovery is off: the
    /// per-group sync costs wire traffic, which fail-loud runs don't pay.
    fn group_fence(&mut self, store: &mut EmbeddingStore) -> Result<()> {
        if self.recovery.is_none() {
            return Ok(());
        }
        let rngs = self.sync_residents(store)?;
        let rec = self.recovery.as_mut().unwrap();
        rec.base_rng = rngs;
        for j in &mut rec.journal {
            j.clear();
        }
        debug_assert!(rec.pending_discards.is_empty());
        Ok(())
    }

    /// [`Self::recover`] from inside a sync fence: nothing is in flight,
    /// so every journal entry is complete and a fold replays only
    /// scatter-only work — the episode counters cannot move.
    fn recover_at_fence(&mut self, store: &mut EmbeddingStore, err: anyhow::Error) -> Result<()> {
        let (mut l, mut t, mut s) = (0.0f64, 0u64, 0u64);
        self.recover(store, err, &mut l, &mut t, &mut s)?;
        anyhow::ensure!(
            t == 0 && s == 0,
            "internal: fence recovery trained {t} samples — fence journals must be complete"
        );
        Ok(())
    }

    /// Apply one sync reply: record the worker's RNG snapshot and scatter
    /// its resident clones home. With recovery on, a re-answered fence
    /// round may deliver duplicates — re-scattering identical bytes is
    /// idempotent, so they are tolerated; without recovery a duplicate is
    /// the PR-7 pointed error.
    fn apply_sync(
        &mut self,
        store: &mut EmbeddingStore,
        sync: SyncReply,
        rngs: &mut [[u64; 4]],
        seen: &mut [bool],
    ) -> Result<()> {
        let n = seen.len();
        anyhow::ensure!(
            sync.worker < n,
            "sync reply from out-of-range worker {} ({n} workers)",
            sync.worker
        );
        anyhow::ensure!(
            self.recovery.is_some() || !seen[sync.worker],
            "duplicate sync reply from worker {} — duplicated delivery",
            sync.worker
        );
        seen[sync.worker] = true;
        rngs[sync.worker] = sync.rng_state;
        let t0 = std::time::Instant::now();
        for part in sync.residents {
            store.scatter_partition(self.parts, part.pid, part.matrix, &part.data);
            self.counters
                .add(&self.counters.bytes_from_device, (part.data.len() * 4) as u64);
        }
        self.counters
            .add(&self.counters.scatter_nanos, t0.elapsed().as_nanos() as u64);
        Ok(())
    }

    /// Fence: pull clones of every worker-resident partition back into
    /// the store (group fences in recovery mode, checkpoints, end of
    /// training) and collect each worker's RNG snapshot, indexed by
    /// worker (replies arrive unordered on the shared channel). Requires
    /// no jobs in flight. With recovery on, a worker dying mid-fence is
    /// recovered (replaced or folded) and the fence retried — Sync is
    /// idempotent worker-side (clones; the RNG does not advance), so
    /// re-answers just re-scatter identical bytes; folded slots answer
    /// from the runner's own RNG chain.
    fn sync_residents(&mut self, store: &mut EmbeddingStore) -> Result<Vec<[u64; 4]>> {
        assert!(self.in_flight.is_empty(), "sync fence with jobs in flight");
        let n = self.transport.num_workers();
        let mut rngs = vec![[0u64; 4]; n];
        let mut seen = vec![false; n];
        loop {
            for s in std::mem::take(&mut self.stray_syncs) {
                self.apply_sync(store, s, &mut rngs, &mut seen)?;
            }
            if let Some(rec) = &self.recovery {
                for w in 0..n {
                    if rec.folded[w] && !seen[w] {
                        seen[w] = true;
                        rngs[w] = rec.folded_rng[w];
                    }
                }
            }
            let discards_pending = self
                .recovery
                .as_ref()
                .is_some_and(|rec| !rec.pending_discards.is_empty());
            if seen.iter().all(|&s| s) && !discards_pending {
                return Ok(rngs);
            }
            // (re-)request every slot still outstanding; a failure in
            // this round is recovered and the whole fence retried
            let mut round_err: Option<anyhow::Error> = None;
            for w in 0..n {
                if seen[w] {
                    continue;
                }
                if let Err(e) = self.transport.send(w, JobMsg::Sync) {
                    round_err = Some(e);
                    break;
                }
            }
            while round_err.is_none() {
                let discards_pending = self
                    .recovery
                    .as_ref()
                    .is_some_and(|rec| !rec.pending_discards.is_empty());
                if seen.iter().all(|&s| s) && !discards_pending {
                    break;
                }
                match self.transport.recv() {
                    Ok(Reply::Synced(sync)) => {
                        self.apply_sync(store, sync, &mut rngs, &mut seen)?
                    }
                    Ok(Reply::Job(res)) => {
                        // only a recovery replay's second delivery is
                        // legal at a fence
                        if let Some(res) = self.discard_replayed(store, res)? {
                            anyhow::bail!(
                                "unexpected job result at sync fence (block ({}, {}))",
                                res.vid,
                                res.cid
                            );
                        }
                    }
                    Ok(Reply::Pong) => {}
                    Err(e) => round_err = Some(e),
                }
            }
            match round_err {
                Some(e) => self.recover_at_fence(store, e)?,
                None => {} // loop re-checks completion and returns
            }
        }
    }
}

/// Run the post-pool observer hook: legacy callbacks get (samples, store)
/// after a residency sync; state observers additionally get the worker
/// RNG snapshots and schedule position as a [`CheckpointState`] and may
/// stop the run at this pool boundary. When a fault-checkpoint stash is
/// given, the full state is additionally cloned into it — the last
/// completed pool boundary an exhausted recovery writes out before dying.
#[allow(clippy::too_many_arguments)]
fn observe_pool(
    observer: &mut Observer,
    runner: &mut EpisodeRunner,
    store: &mut EmbeddingStore,
    cfg: &TrainConfig,
    num_edges: usize,
    num_parts: usize,
    pool_size: usize,
    pools_done: u64,
    samples_done: u64,
    fault_stash: Option<&mut Option<TrainCheckpoint>>,
) -> Result<TrainFlow> {
    if matches!(observer, Observer::None) && fault_stash.is_none() {
        return Ok(TrainFlow::Continue);
    }
    let rngs = runner.sync_residents(store)?;
    let state = CheckpointState {
        seed: cfg.seed,
        num_edges: num_edges as u64,
        partitions: num_parts as u64,
        total_samples: runner.total_samples,
        pool_size: pool_size as u64,
        pools_done,
        samples_planned: runner.samples_planned,
        samples_done,
        worker_rngs: &rngs,
        store: &*store,
    };
    if let Some(stash) = fault_stash {
        *stash = Some(state.to_owned());
    }
    match observer {
        Observer::None => Ok(TrainFlow::Continue),
        Observer::Legacy(cb) => {
            cb(samples_done, store);
            Ok(TrainFlow::Continue)
        }
        Observer::State(cb) => cb(&state),
    }
}

/// Check a loaded checkpoint against the run it is about to continue.
/// Every mismatch here would silently break bitwise equivalence (or scatter
/// out of bounds), so each is a hard error naming both sides.
fn validate_resume(
    ck: &TrainCheckpoint,
    cfg: &TrainConfig,
    graph: &dyn GraphStore,
    num_parts: usize,
    total_samples: u64,
    pool_size: usize,
    num_pools: usize,
) -> Result<()> {
    use anyhow::ensure;
    ensure!(ck.seed == cfg.seed, "checkpoint seed {} != config seed {}", ck.seed, cfg.seed);
    ensure!(
        ck.store.num_nodes() == graph.num_nodes(),
        "checkpoint has {} nodes, graph has {}",
        ck.store.num_nodes(),
        graph.num_nodes()
    );
    ensure!(
        ck.store.dim() == cfg.dim,
        "checkpoint dim {} != config dim {}",
        ck.store.dim(),
        cfg.dim
    );
    ensure!(
        ck.num_edges == graph.num_edges() as u64,
        "checkpoint graph had {} edges, this graph has {}",
        ck.num_edges,
        graph.num_edges()
    );
    ensure!(
        ck.partitions == num_parts as u64,
        "checkpoint used {} partitions, config declares {}",
        ck.partitions,
        num_parts
    );
    ensure!(
        ck.worker_rngs.len() == cfg.num_workers,
        "checkpoint used {} workers, config declares {}",
        ck.worker_rngs.len(),
        cfg.num_workers
    );
    ensure!(
        ck.total_samples == total_samples,
        "checkpoint sample budget is {} but this run's is {} — resume with the same --epochs \
         as the full target run",
        ck.total_samples,
        total_samples
    );
    ensure!(
        ck.pool_size == pool_size as u64,
        "checkpoint pool size {} != this run's {} (episode_size or batch_size changed?)",
        ck.pool_size,
        pool_size
    );
    ensure!(
        (ck.pools_done as usize) < num_pools,
        "checkpoint is already complete ({} of {} pool passes)",
        ck.pools_done,
        num_pools
    );
    Ok(())
}

/// Read-only sampling structures shared by every sampler thread and every
/// pool fill (built once per training run).
struct SamplingShared<'g> {
    walker: Option<RandomWalker<'g>>,
    departure: Option<AliasTableShared>,
    edge_sampler: Option<EdgeSampler>,
}

type AliasTableShared = crate::sampling::AliasTable;

impl<'g> SamplingShared<'g> {
    fn build(graph: &'g dyn GraphStore, cfg: &TrainConfig) -> Self {
        if cfg.online_augmentation {
            SamplingShared {
                walker: Some(RandomWalker::new(graph)),
                departure: Some(OnlineAugmenter::departure_table(graph)),
                edge_sampler: None,
            }
        } else {
            SamplingShared {
                walker: None,
                departure: None,
                edge_sampler: Some(EdgeSampler::new(graph)),
            }
        }
    }
}

/// [`fill_pool_parallel`] plus the `sampling_nanos` accounting — the one
/// fill entry point both the producer thread (collaboration mode) and the
/// sequential path use.
fn fill_pool_counted(
    shared: &SamplingShared<'_>,
    cfg: &TrainConfig,
    base_rng: &Rng,
    counters: &Counters,
    pool_idx: usize,
    target: usize,
    out: &mut SamplePool,
) {
    let t0 = std::time::Instant::now();
    fill_pool_parallel(shared, cfg, base_rng, pool_idx, target, out);
    counters.add(&counters.sampling_nanos, t0.elapsed().as_nanos() as u64);
}

/// Fill one pool with `target` samples using `num_samplers` CPU threads
/// (parallel online augmentation, Algorithm 2), then shuffle (Table 7).
fn fill_pool_parallel(
    shared: &SamplingShared<'_>,
    cfg: &TrainConfig,
    base_rng: &Rng,
    pool_idx: usize,
    target: usize,
    out: &mut SamplePool,
) {
    let num_samplers = cfg.num_samplers;
    let per_thread = target.div_ceil(num_samplers);
    let aug_cfg = AugmentConfig {
        walk_length: cfg.walk_length,
        augmentation_distance: cfg.augmentation_distance,
    };

    let mut parts: Vec<SamplePool> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..num_samplers)
            .map(|i| {
                let rng =
                    base_rng.stream(streams::SAMPLER, (pool_idx as u64) << 16 | i as u64);
                scope.spawn(move || {
                    let mut local = SamplePool::with_capacity(per_thread);
                    match (&shared.walker, &shared.departure, &shared.edge_sampler) {
                        (Some(walker), Some(dep), _) => {
                            let mut aug = OnlineAugmenter::new(walker, dep, aug_cfg, rng);
                            aug.fill(&mut local, per_thread);
                        }
                        (_, _, Some(es)) => {
                            let mut rng = rng;
                            es.fill(&mut local, per_thread, &mut rng);
                        }
                        _ => unreachable!(),
                    }
                    local
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    out.clear();
    out.reserve(target);
    for p in &mut parts {
        out.append(p);
    }
    out.truncate(target);
    let mut rng = base_rng.stream(streams::SHUFFLE, pool_idx as u64);
    shuffle::shuffle(cfg.shuffle, out, cfg.augmentation_distance.max(2), &mut rng);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::pool::ShuffleKind;

    fn small_cfg() -> TrainConfig {
        TrainConfig {
            dim: 8,
            epochs: 3,
            num_workers: 2,
            num_samplers: 2,
            episode_size: 2_000,
            batch_size: 64,
            backend: BackendKind::Native,
            shuffle: ShuffleKind::Pseudo,
            ..TrainConfig::default()
        }
    }

    #[test]
    fn trains_karate_native() {
        let g = generators::karate_club();
        let mut t = Trainer::new(g, TrainConfig { num_workers: 2, ..small_cfg() }).unwrap();
        let r = t.train().unwrap();
        assert_eq!(r.embeddings.num_nodes(), 34);
        assert!(r.stats.counters.samples_trained > 0);
        assert!(r.stats.final_loss.is_finite());
    }

    #[test]
    fn loss_decreases_on_structured_graph() {
        // Empirical gate, swept over PINNED seeds and asserted on the
        // pass rate (ROADMAP "Flaky-threshold audit"): a corrupted
        // pipeline fails to reduce loss on *every* seed, while a single
        // unlucky seed may plateau. Score = head-minus-tail of the loss
        // curve. Floor tightened 0.0 -> 0.01 ("went down at all" ->
        // "went down measurably"): sweep evidence shows every pinned
        // seed dropping the loss by orders of magnitude more than this,
        // while a stalled optimizer jitters around +/- epsilon and now
        // fails instead of squeaking by on a lucky rounding.
        let g = generators::planted_partition(500, 5, 20.0, 0.05, 7);
        let stats = crate::util::gate::seed_sweep(&[5, 6, 7], |seed| {
            let cfg = TrainConfig { epochs: 20, seed, ..small_cfg() };
            let mut t = Trainer::new(g.clone(), cfg).unwrap();
            let r = t.train().unwrap();
            let curve = &r.stats.loss_curve;
            assert!(curve.len() >= 4, "curve {curve:?}");
            let head: f32 = curve[..2].iter().sum::<f32>() / 2.0;
            let tail: f32 = curve[curve.len() - 2..].iter().sum::<f32>() / 2.0;
            (head - tail) as f64
        });
        eprintln!("{}", stats.report("coordinator.loss_decrease", 0.01));
        assert!(stats.pass_rate(0.01) >= 2.0 / 3.0, "{:?}", stats.scores);
    }

    #[test]
    fn sequential_mode_matches_sample_budget() {
        let g = generators::barabasi_albert(300, 3, 3);
        let edges = g.num_edges() as u64;
        let cfg = TrainConfig { collaboration: false, epochs: 2, ..small_cfg() };
        let mut t = Trainer::new(g, cfg).unwrap();
        let r = t.train().unwrap();
        // trained at least the requested budget (pool granularity rounds up)
        assert!(r.stats.counters.samples_trained >= 2 * edges);
    }

    #[test]
    fn ablations_run() {
        let g = generators::barabasi_albert(200, 3, 4);
        for (aug, collab, fixc, pipe, resi) in [
            (false, true, true, true, true),
            (true, false, false, false, true),
            (false, false, false, true, false),
            (true, true, true, false, false),
        ] {
            let cfg = TrainConfig {
                online_augmentation: aug,
                collaboration: collab,
                fix_context: fixc,
                pipeline_transfers: pipe,
                residency: resi,
                epochs: 1,
                ..small_cfg()
            };
            let mut t = Trainer::new(g.clone(), cfg).unwrap();
            let r = t.train().unwrap();
            assert!(r.stats.counters.samples_trained > 0);
        }
    }

    #[test]
    fn more_partitions_than_workers() {
        // paper section 3.2: "any number of partitions greater than n",
        // processed in subgroups of n orthogonal blocks per episode.
        //
        // The micro-F1 gate is empirical, so it is swept over PINNED seeds
        // and asserted on the pass rate (flaky-threshold groundwork, see
        // ROADMAP "Flaky-threshold audit"): pipeline corruption collapses
        // every seed to ~chance, while a single unlucky seed may dip.
        let g = generators::planted_partition(400, 4, 16.0, 0.05, 23);
        let stats = crate::util::gate::seed_sweep(&[42, 43, 44], |seed| {
            let cfg = TrainConfig {
                num_workers: 2,
                num_partitions: 6,
                fix_context: false,
                epochs: 120,
                seed,
                ..small_cfg()
            };
            let mut t = Trainer::new(g.clone(), cfg).unwrap();
            let r = t.train().unwrap();
            assert!(r.stats.counters.samples_trained > 0);
            assert!(r.stats.final_loss.is_finite());
            crate::experiments::classify(&r.embeddings, &g, 0.05, 7).micro_f1
        });
        // floor tightened 0.40 -> 0.45 on sweep evidence (pinned seeds
        // score well above 0.5; chance on 4 balanced classes is 0.25)
        eprintln!("{}", stats.report("more_partitions_than_workers.micro_f1", 0.45));
        // quality must not collapse vs the square grid: at least 2 of the
        // 3 pinned seeds must clear the floor
        assert!(stats.pass_rate(0.45) >= 2.0 / 3.0, "{:?}", stats.scores);
    }

    #[test]
    fn heterogeneous_capacities_train() {
        // ISSUE-4 acceptance shape: 4 partitions streamed through 2
        // unequal "devices" ([1, 3] — one wave of 4 blocks per group)
        // with bounded residency caches (capacity violations fail loudly
        // worker-side, so completion is the assertion).
        let g = generators::barabasi_albert(300, 3, 21);
        let cfg = TrainConfig {
            num_workers: 2,
            worker_capacities: vec![1, 3],
            num_partitions: 4,
            fix_context: false,
            epochs: 2,
            ..small_cfg()
        };
        let mut t = Trainer::new(g, cfg).unwrap();
        let r = t.train().unwrap();
        assert!(r.stats.counters.samples_trained > 0);
        assert!(r.stats.final_loss.is_finite());
    }

    #[test]
    fn partitions_must_be_multiple_of_workers() {
        let g = generators::karate_club();
        let cfg = TrainConfig {
            num_workers: 2,
            num_partitions: 5,
            fix_context: false,
            ..small_cfg()
        };
        assert!(Trainer::new(g, cfg).is_err());
    }

    #[test]
    fn fix_context_rejects_extra_partitions() {
        let g = generators::karate_club();
        let cfg = TrainConfig {
            num_workers: 2,
            num_partitions: 4,
            fix_context: true,
            ..small_cfg()
        };
        assert!(Trainer::new(g, cfg).is_err());
    }

    #[test]
    fn checkpoints_fire() {
        let g = generators::barabasi_albert(200, 3, 5);
        let mut cfg = small_cfg();
        cfg.episode_size = 500; // several pools
        cfg.epochs = 4;
        let mut t = Trainer::new(g, cfg).unwrap();
        let mut calls = 0;
        let mut cb = |done: u64, store: &EmbeddingStore| {
            assert!(done > 0);
            assert_eq!(store.dim(), 8);
            calls += 1;
        };
        t.train_with_callback(Some(&mut cb)).unwrap();
        assert!(calls >= 2, "calls {calls}");
    }
}
