//! The sample pool (paper §3.1–3.3): the buffer of augmented edge samples
//! CPUs produce and GPUs consume, with the shuffle algorithms of Table 7,
//! block redistribution into the n×n grid (Algorithm 3's `Redistribute`)
//! and the double-buffered collaboration pair (§3.3).
//!
//! **Pseudo shuffle (§3.1, Table 7).** Samples from one random walk are
//! correlated (they share nodes), and feeding them to SGD in generation
//! order hurts embedding quality; a full Fisher–Yates pass over a
//! hundred-million-sample pool is a cache-miss storm. The paper's pseudo
//! shuffle is the middle point: deal samples round-robin into `s`
//! sequential-append blocks (s = augmentation distance) and concatenate,
//! so correlated neighbors land ~`pool_len / s` apart at purely
//! sequential-write cost. All
//! four algorithms of Table 7 (`none`, `random`, `index-mapping`,
//! `pseudo`) live in [`shuffle`], selected by [`ShuffleKind`]; the speed
//! column is reproduced by `bench_micro`, the F1 column by `bench_table7`.
//!
//! **Episode semantics (§3.2–3.3).** A filled pool is redistributed into
//! the [`BlockGrid`] — `blocks[i][j]` holds samples whose source lies in
//! vertex partition `i` and target in context partition `j`, already
//! translated to partition-local rows. One *episode* is one orthogonal
//! group: a latin-square diagonal of n mutually orthogonal blocks (each
//! holding ~`episode_size / n` samples, `episode_size` in total) trained
//! by the n workers concurrently (see [`crate::scheduler`]); a *pool
//! pass* is n episodes covering all n² blocks, after which the pair of
//! pools swaps
//! ([`PoolPair`], the §3.3 collaboration strategy): device workers train
//! out of one pool while the sampler threads fill the other, so CPU
//! sampling and GPU training overlap instead of alternating (the
//! `collaboration = false` ablation is exactly that alternation).

mod double_buffer;
pub mod shuffle;

pub use double_buffer::PoolPair;
pub use shuffle::ShuffleKind;

use crate::partition::Partitioning;

/// A pool of (source, target) positive samples.
pub type SamplePool = Vec<(u32, u32)>;

/// Samples redistributed into the n×n partition grid: `blocks[i][j]` holds
/// samples whose source is in vertex partition i and target in context
/// partition j, already translated to *local row* pairs.
#[derive(Debug, Clone)]
pub struct BlockGrid {
    n: usize,
    blocks: Vec<Vec<(i32, i32)>>,
}

/// Below this pool size the parallel redistribute's thread-spawn overhead
/// outweighs the scan, so [`BlockGrid::refill`] falls back to one thread.
const PARALLEL_REDISTRIBUTE_MIN: usize = 1 << 15;

impl BlockGrid {
    /// An empty `n × n` grid ready for [`Self::refill`] (the coordinator
    /// keeps one alive across pool passes so block buffers recycle).
    pub fn new_empty(n: usize) -> Self {
        assert!(n >= 1);
        BlockGrid { n, blocks: vec![Vec::new(); n * n] }
    }

    /// Algorithm 3 `Redistribute(pool)`: scatter pool samples into grid
    /// blocks by (part(u), part(v)), translating to local rows.
    ///
    /// Order within each block preserves pool order — the shuffle applied
    /// to the pool carries through to each block's training order.
    pub fn redistribute(pool: &[(u32, u32)], parts: &Partitioning) -> Self {
        let mut grid = Self::new_empty(parts.num_parts());
        grid.refill(pool, parts, 1, &mut Vec::new());
        grid
    }

    /// Redistribute `pool` into this grid in place, reusing the grid's
    /// own block allocations plus buffers from the `spare` free-list
    /// (blocks that went out to device workers come back through it —
    /// the zero-realloc loop of the transfer engine). Emptied shard
    /// buffers are returned to `spare` for the next pool pass.
    pub fn refill(
        &mut self,
        pool: &[(u32, u32)],
        parts: &Partitioning,
        threads: usize,
        spare: &mut Vec<Vec<(i32, i32)>>,
    ) {
        assert_eq!(self.n, parts.num_parts(), "grid / partitioning mismatch");
        let n = self.n;
        // top up capacity-less slots (taken by jobs) from the free-list
        for b in self.blocks.iter_mut() {
            if b.capacity() == 0 {
                if let Some(s) = spare.pop() {
                    *b = s;
                }
            }
            b.clear();
        }
        let threads = threads.max(1);
        if threads == 1 || pool.len() < PARALLEL_REDISTRIBUTE_MIN {
            // pre-size: expected pool.len() / n^2 per block
            let expect = pool.len() / (n * n) + 1;
            for b in self.blocks.iter_mut() {
                b.reserve(expect);
            }
            for &(u, v) in pool {
                let (pi, pj) = (parts.part_of(u), parts.part_of(v));
                self.blocks[pi * n + pj]
                    .push((parts.local_row(u) as i32, parts.local_row(v) as i32));
            }
        } else {
            let shard = pool.len().div_ceil(threads);
            let mut partials: Vec<Vec<Vec<(i32, i32)>>> = (0..threads)
                .map(|_| {
                    (0..n * n)
                        .map(|_| {
                            spare
                                .pop()
                                .map(|mut b| {
                                    b.clear();
                                    b
                                })
                                .unwrap_or_default()
                        })
                        .collect()
                })
                .collect();
            std::thread::scope(|scope| {
                let mut handles = Vec::with_capacity(threads);
                for (t, partial) in partials.iter_mut().enumerate() {
                    let lo = (t * shard).min(pool.len());
                    let hi = ((t + 1) * shard).min(pool.len());
                    let chunk = &pool[lo..hi];
                    handles.push(scope.spawn(move || {
                        for &(u, v) in chunk {
                            let (pi, pj) = (parts.part_of(u), parts.part_of(v));
                            partial[pi * n + pj]
                                .push((parts.local_row(u) as i32, parts.local_row(v) as i32));
                        }
                    }));
                }
                for h in handles {
                    h.join().unwrap();
                }
            });
            // merge in shard order: concatenating contiguous-chunk partials
            // reproduces pool order inside every block exactly
            for mut partial in partials {
                for (slot, src) in partial.iter_mut().enumerate() {
                    self.blocks[slot].append(src);
                }
                // emptied shard buffers keep their capacity for next pass
                spare.append(&mut partial);
            }
        }
    }

    pub fn num_parts(&self) -> usize {
        self.n
    }

    /// Samples of block (i, j) as local-row pairs.
    #[inline]
    pub fn block(&self, i: usize, j: usize) -> &[(i32, i32)] {
        &self.blocks[i * self.n + j]
    }

    /// Take ownership of block (i, j) (used when sending to a worker).
    pub fn take_block(&mut self, i: usize, j: usize) -> Vec<(i32, i32)> {
        std::mem::take(&mut self.blocks[i * self.n + j])
    }

    pub fn total_samples(&self) -> usize {
        self.blocks.iter().map(|b| b.len()).sum()
    }

    /// Max/min block size ratio (load-balance diagnostic for the zig-zag
    /// partitioner ablation).
    pub fn imbalance(&self) -> f64 {
        let max = self.blocks.iter().map(|b| b.len()).max().unwrap_or(0);
        let min = self.blocks.iter().map(|b| b.len()).min().unwrap_or(0);
        if min == 0 {
            f64::INFINITY
        } else {
            max as f64 / min as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::partition::Partitioner;

    #[test]
    fn redistribute_conserves_samples() {
        let g = generators::barabasi_albert(300, 3, 1);
        let parts = Partitioner::degree_zigzag(&g, 3);
        let pool: Vec<(u32, u32)> = g.edges().map(|(u, v, _)| (u, v)).collect();
        let grid = BlockGrid::redistribute(&pool, &parts);
        assert_eq!(grid.total_samples(), pool.len());
    }

    #[test]
    fn block_membership_correct() {
        let g = generators::barabasi_albert(300, 3, 2);
        let parts = Partitioner::degree_zigzag(&g, 4);
        let pool: Vec<(u32, u32)> = g.edges().map(|(u, v, _)| (u, v)).collect();
        let grid = BlockGrid::redistribute(&pool, &parts);
        for i in 0..4 {
            for j in 0..4 {
                for &(lu, lv) in grid.block(i, j) {
                    // local rows must be valid for their partitions
                    assert!((lu as usize) < parts.part_size(i));
                    assert!((lv as usize) < parts.part_size(j));
                    // and map back to nodes in the right partitions
                    let u = parts.nodes_of_part(i)[lu as usize];
                    let v = parts.nodes_of_part(j)[lv as usize];
                    assert_eq!(parts.part_of(u), i);
                    assert_eq!(parts.part_of(v), j);
                }
            }
        }
    }

    #[test]
    fn take_block_empties() {
        let g = generators::karate_club();
        let parts = Partitioner::degree_zigzag(&g, 2);
        let pool: Vec<(u32, u32)> = g.edges().map(|(u, v, _)| (u, v)).collect();
        let mut grid = BlockGrid::redistribute(&pool, &parts);
        let before = grid.total_samples();
        let blk = grid.take_block(0, 0);
        assert_eq!(grid.total_samples(), before - blk.len());
        assert!(grid.block(0, 0).is_empty());
    }

    #[test]
    fn parallel_redistribute_is_bitwise_identical() {
        let g = generators::barabasi_albert(500, 4, 8);
        let parts = Partitioner::degree_zigzag(&g, 3);
        // repeat edges until the pool crosses the parallel threshold so
        // the sharded path actually runs
        let edges: Vec<(u32, u32)> = g.edges().map(|(u, v, _)| (u, v)).collect();
        let mut pool: Vec<(u32, u32)> = Vec::new();
        while pool.len() < super::PARALLEL_REDISTRIBUTE_MIN + 1000 {
            pool.extend_from_slice(&edges);
        }
        let serial = BlockGrid::redistribute(&pool, &parts);
        for threads in [2, 3, 7] {
            let mut par = BlockGrid::new_empty(3);
            par.refill(&pool, &parts, threads, &mut Vec::new());
            for i in 0..3 {
                for j in 0..3 {
                    assert_eq!(
                        serial.block(i, j),
                        par.block(i, j),
                        "threads={threads} block ({i},{j})"
                    );
                }
            }
        }
    }

    #[test]
    fn refill_recycles_block_buffers() {
        let g = generators::barabasi_albert(300, 3, 4);
        let parts = Partitioner::degree_zigzag(&g, 2);
        let pool: Vec<(u32, u32)> = g.edges().map(|(u, v, _)| (u, v)).collect();
        let mut grid = BlockGrid::new_empty(2);
        let mut spare: Vec<Vec<(i32, i32)>> = Vec::new();
        grid.refill(&pool, &parts, 1, &mut spare);
        let reference = BlockGrid::redistribute(&pool, &parts);
        // simulate the job loop: blocks leave the grid, come back via spare
        for i in 0..2 {
            for j in 0..2 {
                let mut b = grid.take_block(i, j);
                b.clear();
                spare.push(b);
            }
        }
        grid.refill(&pool, &parts, 1, &mut spare);
        for i in 0..2 {
            for j in 0..2 {
                assert_eq!(grid.block(i, j), reference.block(i, j), "block ({i},{j})");
            }
        }
        assert!(spare.is_empty(), "all four recycled buffers should be back in slots");
    }

    #[test]
    fn zigzag_blocks_reasonably_balanced() {
        let g = generators::barabasi_albert(2000, 4, 3);
        let parts = Partitioner::degree_zigzag(&g, 4);
        let pool: Vec<(u32, u32)> = g.edges().map(|(u, v, _)| (u, v)).collect();
        let grid = BlockGrid::redistribute(&pool, &parts);
        assert!(grid.imbalance() < 3.0, "imbalance {}", grid.imbalance());
    }
}
