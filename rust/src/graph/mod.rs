//! Graph substrate: CSR storage (in-RAM and out-of-core), edge-list I/O,
//! synthetic generators and degree statistics.
//!
//! GraphVite treats all networks as undirected weighted graphs
//! (paper section 4.3); [`GraphBuilder`] symmetrizes edges on
//! construction. Everything downstream of construction — walker,
//! samplers, partitioner, stats, trainer — consumes the [`GraphStore`]
//! trait, implemented by both the in-RAM [`Graph`] and the paged
//! on-disk reader [`PagedCsr`] (`graphvite pack` writes its format;
//! see [`ondisk`] for the byte layout).

mod builder;
mod csr;
pub mod generators;
mod loader;
pub mod ondisk;
pub mod reorder;
mod stats;
mod store;

pub use builder::GraphBuilder;
pub use csr::Graph;
pub use loader::{load_edge_list, save_edge_list};
pub use ondisk::{
    load_graph, pack_edge_list, pack_graph, pack_store, CacheStats, GraphFormat, LoadedGraph,
    PackOptions, PackStats, PagedCsr, DEFAULT_PACK_MEM_BYTES,
};
pub use reorder::{bfs_order, invert_order, relabel, ReorderKind};
pub use stats::{degree_histogram, GraphStats};
pub use store::GraphStore;
