//! LINE baseline: multi-threaded hogwild ASGD (Recht et al.) over
//! alias-sampled edges, with degree^0.75 negative sampling — a faithful
//! port of the reference C++ implementation's training loop, including
//! its per-sample immediate (non-mini-batched) updates and linear
//! learning-rate decay.
//!
//! Matches the paper's experimental protocol: the network-augmentation
//! stage (random-walk expansion) is run *offline* and parallelized
//! ("We parallel the network augmentation in LINE"), counted as
//! preprocessing time, then training draws from the augmented sample set.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::Result;

use crate::baselines::BaselineResult;
use crate::embedding::EmbeddingStore;
use crate::graph::Graph;
use crate::metrics::TrainStats;
use crate::sampling::{AliasTable, AugmentConfig, EdgeSampler, OnlineAugmenter, RandomWalker};
use crate::util::rng::Rng;
use crate::util::timer::Stopwatch;

/// Shared embedding matrix with hogwild (racy but benign) writes.
///
/// SAFETY: concurrent unsynchronized f32 writes are data races in the
/// formal sense; hogwild SGD tolerates them (sparse updates rarely
/// collide, and a torn f32 is just a slightly stale gradient). This is
/// exactly what LINE/word2vec do with plain C arrays.
struct SharedMatrix(UnsafeCell<Vec<f32>>);
unsafe impl Sync for SharedMatrix {}

impl SharedMatrix {
    fn new(data: Vec<f32>) -> Self {
        SharedMatrix(UnsafeCell::new(data))
    }

    #[allow(clippy::mut_from_ref)]
    unsafe fn get(&self) -> &mut [f32] {
        &mut *self.0.get()
    }

    fn into_inner(self) -> Vec<f32> {
        self.0.into_inner()
    }
}

/// LINE training configuration (paper-default hyperparameters).
#[derive(Debug, Clone)]
pub struct LineConfig {
    pub dim: usize,
    pub epochs: usize,
    pub lr: f32,
    pub negatives: usize,
    pub neg_weight: f32,
    pub threads: usize,
    /// Offline augmentation: walk length (0 = plain LINE, no augmentation).
    pub walk_length: usize,
    pub augmentation_distance: usize,
    /// Walk coverage: how many times the offline augmentation covers each
    /// edge on average. The materialized set has
    /// `coverage * |E| * augmentation_ratio` samples — the analogue of
    /// LINE's fully materialized augmented network E'. Too small a
    /// multiple starves each node of distinct training partners and caps
    /// embedding quality far below the online sampler's.
    pub aug_coverage: usize,
    pub seed: u64,
}

impl Default for LineConfig {
    fn default() -> Self {
        LineConfig {
            dim: 64,
            epochs: 10,
            lr: 0.025,
            negatives: 1,
            neg_weight: 5.0,
            threads: 4,
            walk_length: 5,
            augmentation_distance: 2,
            aug_coverage: 10,
            seed: 42,
        }
    }
}

/// The LINE system.
pub struct LineBaseline;

impl LineBaseline {
    /// Run LINE end to end: (optional) offline augmentation, then hogwild
    /// SGNS for `epochs * |E|` samples.
    pub fn train(graph: &Graph, cfg: &LineConfig) -> Result<BaselineResult> {
        let mut prep = Stopwatch::started();
        // ---- offline augmentation (preprocessing, parallelized) ----
        let augmented: Vec<(u32, u32)> = if cfg.walk_length > 0 {
            let aug_cfg = AugmentConfig {
                walk_length: cfg.walk_length,
                augmentation_distance: cfg.augmentation_distance,
            };
            let departure = OnlineAugmenter::departure_table(graph);
            let walker = RandomWalker::new(graph);
            let target = cfg.aug_coverage.max(1) * graph.num_edges()
                * OnlineAugmenter::samples_per_walk(&aug_cfg)
                / cfg.walk_length.max(1);
            let per_thread = target.div_ceil(cfg.threads);
            let base = Rng::new(cfg.seed);
            std::thread::scope(|s| {
                let handles: Vec<_> = (0..cfg.threads)
                    .map(|i| {
                        let rng = base.split(i as u64);
                        let departure = &departure;
                        let walker = &walker;
                        s.spawn(move || {
                            let mut out = Vec::with_capacity(per_thread);
                            let mut aug = OnlineAugmenter::new(walker, departure, aug_cfg, rng);
                            aug.fill(&mut out, per_thread);
                            out
                        })
                    })
                    .collect();
                handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
            })
        } else {
            Vec::new()
        };
        // alias table over the augmented edge set (or the raw edges)
        let edge_sampler = if augmented.is_empty() {
            Some(EdgeSampler::new(graph))
        } else {
            None
        };
        let neg_weights: Vec<f32> = (0..graph.num_nodes() as u32)
            .map(|v| graph.weighted_degree(v).max(1e-12).powf(0.75))
            .collect();
        let neg_table = AliasTable::new(&neg_weights);
        prep.stop();

        // ---- hogwild training ----
        let mut train_sw = Stopwatch::started();
        let n = graph.num_nodes();
        let dim = cfg.dim;
        let init = EmbeddingStore::init(n, dim, cfg.seed);
        let vertex = Arc::new(SharedMatrix::new(init.vertex_matrix().to_vec()));
        let context = Arc::new(SharedMatrix::new(init.context_matrix().to_vec()));

        let total: u64 = (cfg.epochs * graph.num_edges()) as u64;
        let done = Arc::new(AtomicU64::new(0));
        let per_thread = total / cfg.threads as u64;

        std::thread::scope(|s| {
            for t in 0..cfg.threads {
                let vertex = Arc::clone(&vertex);
                let context = Arc::clone(&context);
                let done = Arc::clone(&done);
                let mut rng = Rng::new(cfg.seed).split(0x11E ^ t as u64);
                let augmented = &augmented;
                let edge_sampler = edge_sampler.as_ref();
                let neg_table = &neg_table;
                s.spawn(move || {
                    // SAFETY: hogwild — see SharedMatrix.
                    let v = unsafe { vertex.get() };
                    let c = unsafe { context.get() };
                    let my_total = per_thread + u64::from(t == 0) * (total % cfg.threads as u64);
                    for i in 0..my_total {
                        let (src, dst) = if let Some(es) = edge_sampler {
                            es.sample(&mut rng)
                        } else {
                            augmented[rng.below_usize(augmented.len())]
                        };
                        // linear lr decay on global progress (coarse:
                        // update the shared counter every 1024 samples)
                        if i % 1024 == 0 {
                            done.fetch_add(1024.min(my_total - i), Ordering::Relaxed);
                        }
                        let progress = done.load(Ordering::Relaxed) as f32 / total as f32;
                        let lr = cfg.lr * (1.0 - progress).max(1e-4);
                        sgns_update(
                            v, c, dim, src, dst, neg_table, cfg.negatives, cfg.neg_weight, lr,
                            &mut rng,
                        );
                    }
                });
            }
        });
        train_sw.stop();

        let vertex = Arc::try_unwrap(vertex)
            .map_err(|_| anyhow::anyhow!("matrix still shared"))?
            .into_inner();
        let context = Arc::try_unwrap(context)
            .map_err(|_| anyhow::anyhow!("matrix still shared"))?
            .into_inner();
        let mut stats = TrainStats {
            train_secs: train_sw.secs(),
            preprocess_secs: prep.secs(),
            ..Default::default()
        };
        stats.counters.samples_trained = total;
        Ok(BaselineResult {
            embeddings: EmbeddingStore::from_raw(n, dim, vertex, context),
            stats,
        })
    }
}

/// One per-sample immediate SGNS update (word2vec/LINE style).
#[allow(clippy::too_many_arguments)]
#[inline]
pub fn sgns_update(
    vertex: &mut [f32],
    context: &mut [f32],
    dim: usize,
    src: u32,
    dst: u32,
    neg_table: &AliasTable,
    negatives: usize,
    neg_weight: f32,
    lr: f32,
    rng: &mut Rng,
) {
    let u = src as usize * dim;
    let mut u_grad = [0f32; 512];
    let u_grad = &mut u_grad[..dim];

    // positive pair
    {
        let v = dst as usize * dim;
        let (urow, vrow) = (&vertex[u..u + dim], &mut context[v..v + dim]);
        let s: f32 = urow.iter().zip(vrow.iter()).map(|(a, b)| a * b).sum();
        let g = 1.0 / (1.0 + (-s).exp()) - 1.0;
        for j in 0..dim {
            u_grad[j] += g * vrow[j];
            vrow[j] -= lr * g * urow[j];
        }
    }
    // negatives
    for _ in 0..negatives {
        let nv = neg_table.sample(rng) as usize * dim;
        let (urow, nrow) = (&vertex[u..u + dim], &mut context[nv..nv + dim]);
        let s: f32 = urow.iter().zip(nrow.iter()).map(|(a, b)| a * b).sum();
        let g = neg_weight / (1.0 + (-s).exp());
        for j in 0..dim {
            u_grad[j] += g * nrow[j];
            nrow[j] -= lr * g * urow[j];
        }
    }
    let urow = &mut vertex[u..u + dim];
    for j in 0..dim {
        urow[j] -= lr * u_grad[j];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn line_trains_and_separates_communities() {
        let g = generators::planted_partition(300, 2, 16.0, 0.05, 1);
        // sparse-sample regime: quality needs a large multiple of |E|
        // samples (see the aug_coverage docs); 150 epochs is past the knee
        let cfg = LineConfig { dim: 16, epochs: 150, threads: 2, ..Default::default() };
        let r = LineBaseline::train(&g, &cfg).unwrap();
        // SGNS embeddings carry a large common drift component (the ×5
        // negative gradient pushes every vertex away from the mean
        // context); community structure lives in the *centered* space —
        // which is also what any downstream linear classifier sees, since
        // a shared bias direction is absorbed by its weights.
        let labels = g.labels().unwrap();
        let dim = 16;
        let n = g.num_nodes();
        let v = r.embeddings.vertex_matrix();
        let mut mean = vec![0f32; dim];
        for row in v.chunks(dim) {
            for (m, x) in mean.iter_mut().zip(row) {
                *m += x;
            }
        }
        for m in &mut mean {
            *m /= n as f32;
        }
        let mut centered: Vec<f32> = v.to_vec();
        for row in centered.chunks_mut(dim) {
            for (x, m) in row.iter_mut().zip(&mean) {
                *x -= m;
            }
            let norm = row.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-12);
            for x in row {
                *x /= norm;
            }
        }
        let cos = |a: usize, b: usize| -> f32 {
            centered[a * dim..(a + 1) * dim]
                .iter()
                .zip(&centered[b * dim..(b + 1) * dim])
                .map(|(x, y)| x * y)
                .sum()
        };
        let mut intra = 0.0;
        let mut inter = 0.0;
        let mut n_intra = 0;
        let mut n_inter = 0;
        for a in (0..300).step_by(7) {
            for b in (1..300).step_by(11) {
                if a == b {
                    continue;
                }
                if labels[a] == labels[b] {
                    intra += cos(a, b);
                    n_intra += 1;
                } else {
                    inter += cos(a, b);
                    n_inter += 1;
                }
            }
        }
        let (intra, inter) = (intra / n_intra as f32, inter / n_inter as f32);
        assert!(intra > inter + 0.05, "intra {intra} inter {inter}");
    }

    #[test]
    fn plain_line_no_augmentation() {
        let g = generators::barabasi_albert(200, 3, 2);
        let cfg =
            LineConfig { dim: 8, epochs: 2, threads: 2, walk_length: 0, ..Default::default() };
        let r = LineBaseline::train(&g, &cfg).unwrap();
        assert_eq!(r.embeddings.num_nodes(), 200);
        assert!(r.stats.counters.samples_trained >= 2 * g.num_edges() as u64 - 4);
    }

    #[test]
    #[should_panic]
    fn dim_over_512_unsupported_in_update() {
        // sgns_update uses a 512-float stack buffer; document the limit
        let mut v = vec![0.0f32; 1024 * 2];
        let mut c = vec![0.0f32; 1024 * 2];
        let t = AliasTable::new(&[1.0, 1.0]);
        let mut rng = Rng::new(1);
        sgns_update(&mut v, &mut c, 1024, 0, 1, &t, 1, 5.0, 0.01, &mut rng);
    }
}
