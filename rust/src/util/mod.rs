//! Hand-rolled infrastructure substrates.
//!
//! The offline crate set has no `rand`, `rayon`, `criterion` or `proptest`,
//! so this module provides the equivalents the rest of the system needs:
//! a fast counter-seeded RNG ([`rng`]), wall-clock timers ([`timer`]), a
//! criterion-style benchmark harness ([`bench`]), a miniature
//! property-testing framework ([`prop`]) and pinned-seed sweep statistics
//! for empirical quality gates ([`gate`]).

pub mod bench;
pub mod gate;
pub mod prop;
pub mod rng;
pub mod timer;

/// Mean of an f64 slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Format a byte count as a human-readable string (KiB/MiB/GiB).
pub fn human_bytes(bytes: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = bytes as f64;
    let mut unit = 0;
    while v >= 1024.0 && unit < UNITS.len() - 1 {
        v /= 1024.0;
        unit += 1;
    }
    if unit == 0 {
        format!("{} {}", bytes, UNITS[0])
    } else {
        format!("{:.2} {}", v, UNITS[unit])
    }
}

/// Format seconds as "Xh Ym", "Xm Ys" or "X.XXs".
pub fn human_secs(secs: f64) -> String {
    if secs >= 3600.0 {
        format!("{:.0}h {:.0}m", (secs / 3600.0).floor(), (secs % 3600.0) / 60.0)
    } else if secs >= 60.0 {
        format!("{:.0}m {:.1}s", (secs / 60.0).floor(), secs % 60.0)
    } else {
        format!("{:.3}s", secs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
        assert!((stddev(&[2.0, 4.0]) - 1.0).abs() < 1e-12);
        assert_eq!(stddev(&[1.0]), 0.0);
    }

    #[test]
    fn bytes_formatting() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn secs_formatting() {
        assert_eq!(human_secs(0.5), "0.500s");
        assert_eq!(human_secs(90.0), "1m 30.0s");
        assert_eq!(human_secs(7260.0), "2h 1m");
    }
}
