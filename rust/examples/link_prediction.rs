//! Link prediction — the paper's Hyperlink-PLD evaluation (§4.5): hold
//! out a fraction of edges, train on the rest, score held-out pairs vs
//! random non-edges by cosine similarity, and report ROC-AUC.
//!
//!     cargo run --release --example link_prediction [nodes]

use graphvite::eval::{link_prediction_auc, LinkSplit};
use graphvite::prelude::*;

fn main() -> anyhow::Result<()> {
    let nodes: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(5_000);
    // A pure BA graph has no homophily (linked nodes share nothing but
    // preferential attachment), so cosine link prediction is undefined on
    // it; use the youtube-like graph whose community overlay gives edges
    // the locality the paper's Hyperlink-PLD web graph has.
    let graph = generators::youtube_like(nodes, 10, 0xBEEF);
    println!(
        "scale-free + community graph: {} nodes, {} edges",
        graph.num_nodes(),
        graph.num_edges()
    );

    // Hold out 1% of edges (the paper holds out 0.01% of a 623M-edge
    // graph; at our scale 1% keeps the test set meaningfully sized).
    let split = LinkSplit::new(&graph, 0.01, 4);
    println!(
        "held out {} positive edges (+ {} sampled non-edges)",
        split.positives.len(),
        split.negatives.len()
    );

    let config = TrainConfig {
        dim: 32,
        epochs: 200,
        num_workers: 4,
        num_samplers: 4,
        episode_size: (nodes / 2).max(4_000),
        backend: BackendKind::Native,
        ..TrainConfig::default()
    };
    let mut trainer = Trainer::new(split.train_graph.clone(), config)?;
    let result = trainer.train()?;
    println!(
        "trained in {:.2}s ({:.2}M samples/s)",
        result.stats.train_secs,
        result.stats.throughput() / 1e6
    );

    let auc = link_prediction_auc(&result.embeddings, &split);
    println!("link prediction AUC = {auc:.4}  (paper reports 0.943 on Hyperlink-PLD)");
    // Held-out edges mix community edges (predictable) with BA edges (no
    // homophily -> coin-flip), capping AUC near ~0.75 on this workload.
    anyhow::ensure!(auc > 0.55, "AUC suspiciously low: {auc}");
    println!("link_prediction OK");
    Ok(())
}
