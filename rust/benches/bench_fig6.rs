//! Regenerates paper Figure 6 — speedup vs number of CPU samplers and device workers.
//!
//! Run with `cargo bench --bench bench_fig6`; set
//! GRAPHVITE_BENCH_SCALE=tiny|small|full to change the workload size
//! (default tiny so `cargo bench` completes quickly; EXPERIMENTS.md
//! records the `small` runs).

fn scale() -> graphvite::experiments::Scale {
    std::env::var("GRAPHVITE_BENCH_SCALE")
        .ok()
        .and_then(|s| graphvite::experiments::Scale::parse(&s))
        .unwrap_or(graphvite::experiments::Scale::Tiny)
}

fn main() {
    graphvite::experiments::run("fig6", scale()).expect("fig6 experiment");
}
